//! Integrated autocorrelation time (IAT) estimation.
//!
//! The paper's variance decomposition `V ~= sigma_f^2 tau / T` (section 2)
//! uses the IAT `tau`; we estimate it with Geyer's initial positive
//! sequence (IPS) estimator, the standard choice for reversible chains,
//! and report effective sample size `T / tau`.

/// Autocovariance at the given lag (biased, divide-by-n convention).
pub fn autocovariance(xs: &[f64], lag: usize) -> f64 {
    let n = xs.len();
    assert!(lag < n);
    let mean = xs.iter().sum::<f64>() / n as f64;
    let mut s = 0.0;
    for i in 0..n - lag {
        s += (xs[i] - mean) * (xs[i + lag] - mean);
    }
    s / n as f64
}

/// Geyer initial-positive-sequence IAT estimate.
///
/// tau = 1 + 2 sum_k rho_k, truncated at the first k where the paired sum
/// Gamma_m = rho_{2m} + rho_{2m+1} turns non-positive.
pub fn integrated_autocorr_time(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 4 {
        return 1.0;
    }
    let c0 = autocovariance(xs, 0);
    if c0 <= 0.0 {
        return 1.0;
    }
    let max_lag = (n - 1).min(n / 2);
    let mut tau = 1.0;
    let mut m = 0;
    loop {
        let l1 = 2 * m + 1;
        let l2 = 2 * m + 2;
        if l2 > max_lag {
            break;
        }
        let gamma = (autocovariance(xs, l1) + autocovariance(xs, l2)) / c0;
        if gamma <= 0.0 {
            break;
        }
        tau += 2.0 * gamma;
        m += 1;
    }
    tau.max(1.0)
}

/// Effective sample size T / tau.
pub fn effective_sample_size(xs: &[f64]) -> f64 {
    xs.len() as f64 / integrated_autocorr_time(xs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;

    #[test]
    fn iid_has_tau_near_one() {
        let mut rng = Pcg64::seeded(0);
        let xs: Vec<f64> = (0..20_000).map(|_| rng.normal()).collect();
        let tau = integrated_autocorr_time(&xs);
        assert!(tau < 1.2, "tau={tau}");
    }

    #[test]
    fn ar1_tau_matches_theory() {
        // AR(1) x_t = a x_{t-1} + e: tau = (1+a)/(1-a).
        let a: f64 = 0.8;
        let mut rng = Pcg64::seeded(1);
        let mut x = 0.0;
        let xs: Vec<f64> = (0..200_000)
            .map(|_| {
                x = a * x + rng.normal() * (1.0 - a * a).sqrt();
                x
            })
            .collect();
        let tau = integrated_autocorr_time(&xs);
        let want = (1.0 + a) / (1.0 - a); // 9
        assert!((tau - want).abs() / want < 0.15, "tau={tau} want={want}");
    }

    #[test]
    fn constant_series_degenerate() {
        let xs = vec![2.5; 100];
        assert_eq!(integrated_autocorr_time(&xs), 1.0);
    }

    #[test]
    fn ess_bounded_by_n() {
        let mut rng = Pcg64::seeded(2);
        let mut x = 0.0;
        let xs: Vec<f64> = (0..5_000)
            .map(|_| {
                x = 0.5 * x + rng.normal();
                x
            })
            .collect();
        let ess = effective_sample_size(&xs);
        assert!(ess > 0.0 && ess <= xs.len() as f64);
        assert!(ess < 0.9 * xs.len() as f64); // correlated: well below n
    }
}

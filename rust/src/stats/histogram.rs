//! Fixed-bin histogram used by the SGLD pitfall figure (empirical sample
//! density vs true posterior) and the t-statistic distribution figure.

/// Equal-width histogram over [lo, hi]; out-of-range samples are clamped
/// into the edge bins (and counted, so densities stay normalized).
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            total: 0,
        }
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1) as usize;
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn add_all(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add(x);
        }
    }

    pub fn bins(&self) -> usize {
        self.counts.len()
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn count(&self, i: usize) -> u64 {
        self.counts[i]
    }

    /// Center of bin i.
    pub fn center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins() as f64;
        self.lo + (i as f64 + 0.5) * w
    }

    pub fn bin_width(&self) -> f64 {
        (self.hi - self.lo) / self.bins() as f64
    }

    /// Normalized density estimate at bin i (integrates to 1).
    pub fn density(&self, i: usize) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.counts[i] as f64 / (self.total as f64 * self.bin_width())
    }

    /// L1 distance between this (normalized) histogram and a density
    /// evaluated at bin centers — the figure-5 comparison metric.
    pub fn l1_vs_density<F: Fn(f64) -> f64>(&self, f: F) -> f64 {
        let w = self.bin_width();
        (0..self.bins())
            .map(|i| (self.density(i) - f(self.center(i))).abs() * w)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;

    #[test]
    fn density_integrates_to_one() {
        let mut h = Histogram::new(-3.0, 3.0, 50);
        let mut rng = Pcg64::seeded(0);
        for _ in 0..10_000 {
            h.add(rng.normal());
        }
        let integral: f64 = (0..h.bins()).map(|i| h.density(i) * h.bin_width()).sum();
        assert!((integral - 1.0).abs() < 1e-12);
    }

    #[test]
    fn out_of_range_clamped() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.add(-5.0);
        h.add(5.0);
        assert_eq!(h.count(0), 1);
        assert_eq!(h.count(9), 1);
        assert_eq!(h.total(), 2);
    }

    #[test]
    fn normal_histogram_close_to_pdf() {
        let mut h = Histogram::new(-4.0, 4.0, 40);
        let mut rng = Pcg64::seeded(1);
        for _ in 0..200_000 {
            h.add(rng.normal());
        }
        let l1 = h.l1_vs_density(crate::stats::normal::phi_pdf);
        assert!(l1 < 0.05, "l1={l1}");
    }

    #[test]
    fn centers_are_monotone() {
        let h = Histogram::new(-1.0, 1.0, 4);
        assert!((h.center(0) - (-0.75)).abs() < 1e-12);
        assert!((h.center(3) - 0.75).abs() < 1e-12);
    }
}

//! Deterministic, dependency-free PRNG for the whole coordinator.
//!
//! PCG64 (pcg_xsl_rr_128_64): a small, fast generator with a 2^127 period
//! and independent streams, so every chain / replica / experiment can be
//! seeded reproducibly. All randomness in the library flows through this
//! type — no global state, every experiment takes an explicit seed.

/// PCG64 XSL-RR generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id (any values work).
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e_39cb_94b9_5bdb) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Convenience single-seed constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (for per-chain seeding).
    pub fn split(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1)
    }

    /// Raw generator state as four little-endian words
    /// `[state_lo, state_hi, inc_lo, inc_hi]` — the checkpoint layer's
    /// serialization format. Restoring via [`Pcg64::from_parts`] resumes
    /// the stream at exactly this position.
    pub fn state_parts(&self) -> [u64; 4] {
        [
            self.state as u64,
            (self.state >> 64) as u64,
            self.inc as u64,
            (self.inc >> 64) as u64,
        ]
    }

    /// Rebuild a generator from [`Pcg64::state_parts`] output.
    pub fn from_parts(parts: [u64; 4]) -> Self {
        Pcg64 {
            state: (parts[0] as u128) | ((parts[1] as u128) << 64),
            inc: (parts[2] as u128) | ((parts[3] as u128) << 64),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a Metropolis-Hastings u (log u finite).
    #[inline]
    pub fn uniform_pos(&mut self) -> f64 {
        1.0 - self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // Lemire's nearly-divisionless method on 64 bits.
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Marsaglia polar (exact, no table).
    pub fn normal(&mut self) -> f64 {
        loop {
            let a = 2.0 * self.uniform() - 1.0;
            let b = 2.0 * self.uniform() - 1.0;
            let s = a * a + b * b;
            if s > 0.0 && s < 1.0 {
                return a * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// N(mu, sigma^2) sample.
    #[inline]
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Fill `out` with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Laplace(0, b) sample (inverse-CDF).
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.uniform() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            let v = rng.uniform_pos();
            assert!(v > 0.0 && v <= 1.0);
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut rng = Pcg64::seeded(4);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let u = rng.uniform();
            s += u;
            s2 += u * u;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 3e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 3e-3, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Pcg64::seeded(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            let k = rng.below(7);
            counts[k] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(6);
        let n = 200_000;
        let (mut s, mut s2, mut s3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s += z;
            s2 += z * z;
            s3 += z * z * z;
        }
        assert!((s / n as f64).abs() < 0.01);
        assert!((s2 / n as f64 - 1.0).abs() < 0.02);
        assert!((s3 / n as f64).abs() < 0.05);
    }

    #[test]
    fn laplace_variance_is_2b2() {
        let mut rng = Pcg64::seeded(7);
        let b = 0.5;
        let n = 200_000;
        let mut s2 = 0.0;
        for _ in 0..n {
            let z = rng.laplace(b);
            s2 += z * z;
        }
        assert!((s2 / n as f64 - 2.0 * b * b).abs() < 0.02);
    }

    #[test]
    fn state_roundtrip_resumes_stream_exactly() {
        let mut a = Pcg64::new(99, 17);
        for _ in 0..37 {
            a.next_u64();
        }
        let mut b = Pcg64::from_parts(a.state_parts());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(8);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}

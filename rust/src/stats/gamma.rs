//! Regularized incomplete gamma functions and the chi-square tail —
//! the p-value machinery of the statistical-validation testkit
//! (`testkit::validate::chi_square_hist`).
//!
//! `gamma_p`/`gamma_q` follow the classic series / Lentz continued
//! fraction split (Numerical Recipes gammp/gammq), accurate to ~1e-12;
//! `ln_gamma` is shared with the Student-t machinery in `student_t`.

use super::student_t::ln_gamma;

/// Regularized lower incomplete gamma `P(a, x) = gamma(a, x) / Gamma(a)`.
pub fn gamma_p(a: f64, x: f64) -> f64 {
    assert!(a > 0.0, "gamma_p: a={a}");
    if x <= 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        gser(a, x)
    } else {
        1.0 - gcf(a, x)
    }
}

/// Regularized upper incomplete gamma `Q(a, x) = 1 - P(a, x)`, computed
/// without cancellation in the far tail.
pub fn gamma_q(a: f64, x: f64) -> f64 {
    assert!(a > 0.0, "gamma_q: a={a}");
    if x <= 0.0 {
        return 1.0;
    }
    if x < a + 1.0 {
        1.0 - gser(a, x)
    } else {
        gcf(a, x)
    }
}

/// Series representation of P(a, x), convergent for x < a + 1.
fn gser(a: f64, x: f64) -> f64 {
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..500 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * 1e-16 {
            break;
        }
    }
    sum * (-x + a * x.ln() - ln_gamma(a)).exp()
}

/// Lentz continued fraction for Q(a, x), convergent for x >= a + 1.
fn gcf(a: f64, x: f64) -> f64 {
    const FPMIN: f64 = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / FPMIN;
    let mut d = 1.0 / b.max(FPMIN);
    let mut h = d;
    for i in 1..=500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = b + an / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-15 {
            break;
        }
    }
    (-x + a * x.ln() - ln_gamma(a)).exp() * h
}

/// Chi-square CDF with `k` degrees of freedom.
pub fn chi2_cdf(x: f64, k: f64) -> f64 {
    assert!(k > 0.0, "chi2_cdf: k={k}");
    gamma_p(0.5 * k, 0.5 * x)
}

/// Chi-square upper tail (the goodness-of-fit p-value).
pub fn chi2_sf(x: f64, k: f64) -> f64 {
    assert!(k > 0.0, "chi2_sf: k={k}");
    gamma_q(0.5 * k, 0.5 * x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::normal::erf;

    #[test]
    fn p_and_q_are_complementary() {
        for &a in &[0.5, 1.0, 2.5, 10.0, 60.0] {
            for &x in &[0.01, 0.5, 1.0, 3.0, 12.0, 80.0] {
                let s = gamma_p(a, x) + gamma_q(a, x);
                assert!((s - 1.0).abs() < 1e-12, "a={a} x={x}: {s}");
            }
        }
    }

    #[test]
    fn half_dof_matches_erf() {
        // P(1/2, x) = erf(sqrt(x))
        for &x in &[0.01, 0.2, 1.0, 2.5, 9.0] {
            let got = gamma_p(0.5, x);
            let want = erf(x.sqrt());
            assert!((got - want).abs() < 1e-12, "x={x}: {got} vs {want}");
        }
    }

    #[test]
    fn chi2_two_dof_is_exponential() {
        // sf(x; 2) = e^{-x/2} exactly
        for &x in &[0.0, 0.3, 1.0, 4.0, 11.0, 30.0] {
            let got = chi2_sf(x, 2.0);
            let want = (-0.5 * x).exp();
            assert!((got - want).abs() < 1e-12, "x={x}");
        }
    }

    #[test]
    fn chi2_critical_values() {
        // chi^2_{0.95, 1} = 3.841458820694124
        assert!((chi2_sf(3.841458820694124, 1.0) - 0.05).abs() < 1e-9);
        assert!(chi2_sf(0.0, 5.0) == 1.0);
        assert!((chi2_cdf(0.0, 5.0)).abs() < 1e-15);
    }

    #[test]
    fn chi2_sf_monotone_decreasing() {
        for &k in &[1.0, 4.0, 9.0, 30.0] {
            let mut prev = 1.0 + 1e-12;
            for i in 0..200 {
                let x = i as f64 * 0.5;
                let s = chi2_sf(x, k);
                assert!(s <= prev, "k={k} x={x}");
                assert!((0.0..=1.0).contains(&s));
                prev = s;
            }
        }
    }
}

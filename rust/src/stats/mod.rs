//! Statistics substrate: RNG, special functions, moment accumulators,
//! quadrature, autocorrelation, histograms.
//!
//! Everything here is dependency-free and deterministic given a seed —
//! the foundation the sequential-test coordinator is built on.

pub mod autocorr;
pub mod gamma;
pub mod histogram;
pub mod logistic_corr;
pub mod normal;
pub mod quadrature;
pub mod rng;
pub mod student_t;
pub mod welford;

pub use histogram::Histogram;
pub use rng::Pcg64;
pub use welford::{MomentAccumulator, Welford};

//! Student-t distribution CDF — the decision rule of the sequential test.
//!
//! The approximate MH test computes `delta = 1 - F_{n-1}(|t|)` where
//! `F_nu` is the CDF of the standard Student-t with `nu` degrees of
//! freedom (paper Alg. 1, line 8). We evaluate it through the regularized
//! incomplete beta function with a Lentz continued fraction — accurate to
//! ~1e-14 for all nu >= 1 and cheap enough (~100 ns) to sit on the
//! per-mini-batch hot path.

use super::normal::phi_sf;

/// Natural log of the gamma function (Lanczos, g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection: Gamma(x) Gamma(1-x) = pi / sin(pi x)
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized incomplete beta I_x(a, b) via Lentz's continued fraction.
pub fn beta_inc(a: f64, b: f64, x: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0, "beta_inc: a={a} b={b}");
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front =
        ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    // Use the symmetry that keeps the continued fraction convergent.
    if x < (a + 1.0) / (a + b + 2.0) {
        front * betacf(a, b, x) / a
    } else {
        1.0 - (ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b)
            + a * x.ln()
            + b * (1.0 - x).ln())
            .exp()
            * betacf(b, a, 1.0 - x)
            / b
    }
}

/// Continued fraction for the incomplete beta (Numerical Recipes betacf).
fn betacf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 3e-16;
    const FPMIN: f64 = 1e-300;

    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        // even step
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        // odd step
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Student-t CDF with `nu` degrees of freedom.
pub fn t_cdf(t: f64, nu: f64) -> f64 {
    assert!(nu > 0.0, "t_cdf: nu={nu}");
    if !t.is_finite() {
        return if t > 0.0 { 1.0 } else { 0.0 };
    }
    // For large nu the t distribution is numerically normal; the beta CF
    // also converges slowly there, so switch over.
    if nu > 1e7 {
        return 1.0 - phi_sf(t);
    }
    let x = nu / (nu + t * t);
    let p = 0.5 * beta_inc(0.5 * nu, 0.5, x);
    if t > 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Upper-tail probability `1 - F_nu(t)` without cancellation for t > 0.
pub fn t_sf(t: f64, nu: f64) -> f64 {
    assert!(nu > 0.0);
    if !t.is_finite() {
        return if t > 0.0 { 0.0 } else { 1.0 };
    }
    if nu > 1e7 {
        return phi_sf(t);
    }
    let x = nu / (nu + t * t);
    let p = 0.5 * beta_inc(0.5 * nu, 0.5, x);
    if t > 0.0 {
        p
    } else {
        1.0 - p
    }
}

/// Two-sided tail `delta = 1 - F_nu(|t|)` — exactly Alg. 1 line 8.
#[inline]
pub fn t_tail(t_abs: f64, nu: f64) -> f64 {
    t_sf(t_abs.abs(), nu)
}

/// Inverse CDF of the Student-t.
///
/// Boundary and tiny-nu behavior is fully defined so sequential-test
/// thresholds can never be NaN on a first mini-batch: `p = 0` / `p = 1`
/// return the infinities (an eps-0 design means "never stop early"),
/// and `nu = 1` (Cauchy) / `nu = 2` use exact closed forms — the generic
/// Newton/bisection path would need enormous brackets in these
/// infinite-variance regimes. Everything else is bisection + Newton on
/// the exact CDF.
pub fn t_inv(p: f64, nu: f64) -> f64 {
    assert!(nu > 0.0, "t_inv: nu={nu}");
    assert!((0.0..=1.0).contains(&p), "t_inv domain: p={p}");
    if p == 0.0 {
        return f64::NEG_INFINITY;
    }
    if p == 1.0 {
        return f64::INFINITY;
    }
    if p == 0.5 {
        return 0.0;
    }
    if nu == 1.0 {
        // Cauchy quantile
        return (std::f64::consts::PI * (p - 0.5)).tan();
    }
    if nu == 2.0 {
        // F(t) = 1/2 + t / (2 sqrt(2 + t^2)) inverts in closed form
        let x = 2.0 * p - 1.0;
        return x * (2.0 / (1.0 - x * x)).sqrt();
    }
    // Bracket by doubling out from the normal quantile (heavy tails at
    // small nu — Cauchy p=0.001 is near -318 — need a dynamic bracket).
    let z = super::normal::phi_inv(p);
    let mut lo = z.abs().mul_add(-4.0, -30.0);
    let mut hi = z.abs().mul_add(4.0, 30.0);
    while t_cdf(lo, nu) > p {
        lo *= 4.0;
    }
    while t_cdf(hi, nu) < p {
        hi *= 4.0;
    }
    let mut x = z;
    for _ in 0..200 {
        let f = t_cdf(x, nu) - p;
        if f.abs() < 1e-14 {
            break;
        }
        if f > 0.0 {
            hi = x;
        } else {
            lo = x;
        }
        // Newton step with bisection fallback.
        let pdf = t_pdf(x, nu);
        let step = f / pdf.max(1e-300);
        let xn = x - step;
        x = if xn > lo && xn < hi { xn } else { 0.5 * (lo + hi) };
    }
    x
}

/// Student-t PDF.
pub fn t_pdf(x: f64, nu: f64) -> f64 {
    let ln = ln_gamma(0.5 * (nu + 1.0))
        - ln_gamma(0.5 * nu)
        - 0.5 * (nu * std::f64::consts::PI).ln()
        - 0.5 * (nu + 1.0) * (x * x / nu).ln_1p();
    ln.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known() {
        // Gamma(0.5) = sqrt(pi), Gamma(1)=1, Gamma(5)=24
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-12);
        assert!(ln_gamma(1.0).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-12);
        assert!((ln_gamma(10.5) - 1_133_278.388_948_904_7f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn beta_inc_bounds_and_symmetry() {
        assert_eq!(beta_inc(2.0, 3.0, 0.0), 0.0);
        assert_eq!(beta_inc(2.0, 3.0, 1.0), 1.0);
        for &(a, b, x) in &[(0.5, 0.5, 0.3), (2.0, 5.0, 0.7), (10.0, 0.5, 0.99)] {
            let s = beta_inc(a, b, x) + beta_inc(b, a, 1.0 - x);
            assert!((s - 1.0).abs() < 1e-12, "a={a} b={b} x={x}: {s}");
        }
    }

    #[test]
    fn beta_inc_uniform_case() {
        // I_x(1,1) = x
        for i in 1..20 {
            let x = i as f64 / 20.0;
            assert!((beta_inc(1.0, 1.0, x) - x).abs() < 1e-13);
        }
    }

    #[test]
    fn t_cdf_reference_values() {
        // scipy.stats.t.cdf reference values.
        // mpmath reference values (30 digits, regularized incomplete beta).
        let cases = [
            (0.0, 1.0, 0.5),
            (1.0, 1.0, 0.75), // Cauchy: 1/2 + atan(1)/pi
            (2.0, 2.0, 0.908248290463863),
            (1.5, 10.0, 0.9177463367772799),
            (-2.5, 30.0, 0.009057824534033345),
            (3.0, 499.0, 0.9985826173820914),
        ];
        for (t, nu, want) in cases {
            let got = t_cdf(t, nu);
            assert!((got - want).abs() < 1e-9, "t={t} nu={nu}: got {got} want {want}");
        }
    }

    #[test]
    fn t_sf_matches_one_minus_cdf_where_stable() {
        for &nu in &[1.0, 4.0, 29.0, 499.0] {
            for i in -40..40 {
                let t = i as f64 / 8.0;
                let a = t_sf(t, nu);
                let b = 1.0 - t_cdf(t, nu);
                assert!((a - b).abs() < 1e-11, "t={t} nu={nu}");
            }
        }
    }

    #[test]
    fn t_cdf_monotone_in_t() {
        for &nu in &[1.0, 9.0, 99.0] {
            let mut prev = 0.0;
            for i in -60..=60 {
                let c = t_cdf(i as f64 / 10.0, nu);
                assert!(c >= prev, "nu={nu} i={i}");
                prev = c;
            }
        }
    }

    #[test]
    fn t_cdf_approaches_normal_for_large_nu() {
        for i in -30..=30 {
            let t = i as f64 / 10.0;
            let diff = (t_cdf(t, 1e6) - super::super::normal::phi_cdf(t)).abs();
            assert!(diff < 2e-7, "t={t} diff={diff:e}");
        }
    }

    #[test]
    fn t_inv_round_trip() {
        for &nu in &[1.0, 2.0, 3.0, 10.0, 100.0, 499.0] {
            for &p in &[0.001, 0.05, 0.3, 0.5, 0.9, 0.975, 0.9999] {
                let t = t_inv(p, nu);
                assert!((t_cdf(t, nu) - p).abs() < 1e-10, "nu={nu} p={p}");
            }
        }
    }

    #[test]
    fn t_inv_closed_forms_pin_table_values() {
        // classical t-table constants for the closed-form nus
        let cases = [
            (0.95, 1.0, 6.313751514675043),
            (0.975, 1.0, 12.706204736432095),
            (0.99, 1.0, 31.820515953773958),
            (0.95, 2.0, 2.919985580353726),
            (0.975, 2.0, 4.302652729911275),
            (0.99, 2.0, 6.964556734283583),
        ];
        for (p, nu, want) in cases {
            let got = t_inv(p, nu);
            assert!(
                ((got - want) / want).abs() < 1e-9,
                "t_inv({p}, {nu}) = {got}, want {want}"
            );
            // symmetry of the lower quantile
            assert!((t_inv(1.0 - p, nu) + got).abs() < 1e-9 * want);
        }
    }

    #[test]
    fn t_inv_boundaries_are_infinite_not_nan() {
        for &nu in &[1.0, 2.0, 3.0, 100.0] {
            assert_eq!(t_inv(1.0, nu), f64::INFINITY);
            assert_eq!(t_inv(0.0, nu), f64::NEG_INFINITY);
            // a first sequential-test stage at m = 2 (nu = 1) with a tiny
            // eps must produce a finite, non-NaN threshold
            let thr = t_inv(1.0 - 1e-12, nu);
            assert!(thr.is_finite() && thr > 0.0, "nu={nu}: {thr}");
        }
    }

    #[test]
    fn t_tail_symmetric() {
        for &nu in &[2.0, 20.0, 200.0] {
            for &t in &[0.0, 0.5, 1.7, 3.3] {
                assert!((t_tail(t, nu) - t_tail(-t, nu)).abs() < 1e-15);
            }
        }
    }
}

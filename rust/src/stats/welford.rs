//! Moment accumulators for the sequential test.
//!
//! The Pallas/native backends hand back per-mini-batch sums
//! `(sum l, sum l^2, count)`; the sequential test needs the running
//! sample mean and the paper's standard-deviation estimate
//!
//! ```text
//! s_l = sqrt((l2bar - lbar^2) * n / (n - 1))              (unbiased)
//! s   = s_l / sqrt(n) * sqrt(1 - (n - 1)/(N - 1))         (Eqn. 4)
//! ```
//!
//! `MomentAccumulator` tracks the raw sums (matching Alg. 1's lbar /
//! l2bar updates exactly); `Welford` is the numerically-hardened
//! alternative used where single-pass variance over long streams is
//! needed (risk estimates, IAT).

/// Raw-sum accumulator mirroring Alg. 1 state (lbar, l2bar, n).
#[derive(Clone, Copy, Debug, Default)]
pub struct MomentAccumulator {
    sum: f64,
    sum_sq: f64,
    n: usize,
}

impl MomentAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold in one mini-batch worth of kernel outputs.
    #[inline]
    pub fn add_batch(&mut self, sum_l: f64, sum_l2: f64, count: usize) {
        self.sum += sum_l;
        self.sum_sq += sum_l2;
        self.n += count;
    }

    /// Fold in a single datapoint.
    #[inline]
    pub fn add(&mut self, l: f64) {
        self.add_batch(l, l * l, 1);
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Sample mean lbar.
    #[inline]
    pub fn mean(&self) -> f64 {
        assert!(self.n > 0);
        self.sum / self.n as f64
    }

    /// Unbiased sample standard deviation s_l.
    pub fn sample_std(&self) -> f64 {
        assert!(self.n > 1, "need n >= 2 for a std estimate");
        let n = self.n as f64;
        let mean = self.sum / n;
        let var = (self.sum_sq / n - mean * mean) * n / (n - 1.0);
        var.max(0.0).sqrt()
    }

    /// Std of the mean with the finite-population correction (Eqn. 4).
    pub fn mean_std_fpc(&self, population: usize) -> f64 {
        let n = self.n as f64;
        let cap_n = population as f64;
        debug_assert!(self.n <= population);
        let fpc = (1.0 - (n - 1.0) / (cap_n - 1.0)).max(0.0);
        self.sample_std() / n.sqrt() * fpc.sqrt()
    }

    /// Paper Eqn. 5 test statistic t = (lbar - mu0) / s.
    pub fn t_statistic(&self, mu0: f64, population: usize) -> f64 {
        let s = self.mean_std_fpc(population);
        if s == 0.0 {
            // All data consumed (or zero variance): decision is exact.
            return if self.mean() > mu0 {
                f64::INFINITY
            } else {
                f64::NEG_INFINITY
            };
        }
        (self.mean() - mu0) / s
    }
}

/// Welford/Chan single-pass mean+variance with merge.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
    }

    #[inline]
    pub fn n(&self) -> u64 {
        self.n
    }

    #[inline]
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (divide by n).
    pub fn var_pop(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (divide by n-1).
    pub fn var_sample(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_sample(&self) -> f64 {
        self.var_sample().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;

    #[test]
    fn moments_match_two_pass() {
        let mut rng = Pcg64::seeded(0);
        let xs: Vec<f64> = (0..1000).map(|_| rng.normal_scaled(3.0, 2.0)).collect();
        let mut acc = MomentAccumulator::new();
        for &x in &xs {
            acc.add(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((acc.mean() - mean).abs() < 1e-12);
        assert!((acc.sample_std() - var.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn batch_and_pointwise_agree() {
        let mut rng = Pcg64::seeded(1);
        let xs: Vec<f64> = (0..500).map(|_| rng.uniform()).collect();
        let mut a = MomentAccumulator::new();
        let mut b = MomentAccumulator::new();
        for &x in &xs {
            a.add(x);
        }
        let (mut s, mut s2) = (0.0, 0.0);
        for &x in &xs[..200] {
            s += x;
            s2 += x * x;
        }
        b.add_batch(s, s2, 200);
        let (mut s, mut s2) = (0.0, 0.0);
        for &x in &xs[200..] {
            s += x;
            s2 += x * x;
        }
        b.add_batch(s, s2, 300);
        assert!((a.mean() - b.mean()).abs() < 1e-12);
        assert!((a.sample_std() - b.sample_std()).abs() < 1e-12);
    }

    #[test]
    fn fpc_zero_when_all_data_used() {
        let mut acc = MomentAccumulator::new();
        for i in 0..100 {
            acc.add(i as f64);
        }
        let s = acc.mean_std_fpc(100);
        assert!(s.abs() < 1e-9, "s={s}");
        // t statistic becomes an exact +/- infinity decision
        assert_eq!(acc.t_statistic(0.0, 100), f64::INFINITY);
        assert_eq!(acc.t_statistic(1e9, 100), f64::NEG_INFINITY);
    }

    #[test]
    fn fpc_reduces_std() {
        let mut acc = MomentAccumulator::new();
        let mut rng = Pcg64::seeded(2);
        for _ in 0..500 {
            acc.add(rng.normal());
        }
        let plain = acc.sample_std() / (500f64).sqrt();
        let fpc = acc.mean_std_fpc(10_000);
        assert!(fpc < plain);
        assert!(fpc > 0.9 * plain); // n << N: correction is mild
    }

    #[test]
    fn welford_matches_moment_acc() {
        let mut rng = Pcg64::seeded(3);
        let mut w = Welford::new();
        let mut m = MomentAccumulator::new();
        for _ in 0..10_000 {
            let x = rng.normal_scaled(-1.0, 0.1);
            w.add(x);
            m.add(x);
        }
        assert!((w.mean() - m.mean()).abs() < 1e-12);
        assert!((w.std_sample() - m.sample_std()).abs() < 1e-10);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let mut rng = Pcg64::seeded(4);
        let xs: Vec<f64> = (0..3000).map(|_| rng.laplace(1.0)).collect();
        let mut whole = Welford::new();
        for &x in &xs {
            whole.add(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..1234] {
            a.add(x);
        }
        for &x in &xs[1234..] {
            b.add(x);
        }
        a.merge(&b);
        assert_eq!(a.n(), whole.n());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.var_sample() - whole.var_sample()).abs() < 1e-10);
    }

    #[test]
    fn t_statistic_sign() {
        let mut acc = MomentAccumulator::new();
        let mut rng = Pcg64::seeded(5);
        for _ in 0..50 {
            acc.add(rng.normal_scaled(2.0, 1.0));
        }
        assert!(acc.t_statistic(0.0, 10_000) > 0.0);
        assert!(acc.t_statistic(4.0, 10_000) < 0.0);
    }
}

//! 1-d numerical quadrature for the acceptance-probability error
//! `Delta = int_{Pa}^{1} E(mu_std(u)) du - int_0^{Pa} E(mu_std(u)) du`
//! (paper Eqn. 6 / supp. Eqn. 22) and the design objective E_u[pi_bar].
//!
//! Gauss-Legendre fixed rules (mapped to arbitrary [a, b]) plus an
//! adaptive Simpson fallback for integrands with a sharp feature (the
//! error E spikes near u where mu_0(u) = mu).

/// Nodes/weights of the 32-point Gauss-Legendre rule on [-1, 1]
/// (positive half; the rule is symmetric).
const GL32_X: [f64; 16] = [
    0.048_307_665_687_738_32,
    0.144_471_961_582_796_5,
    0.239_287_362_252_137_1,
    0.331_868_602_282_127_65,
    0.421_351_276_130_635_3,
    0.506_899_908_932_229_4,
    0.587_715_757_240_762_3,
    0.663_044_266_930_215_2,
    0.732_182_118_740_289_7,
    0.794_483_795_967_942_4,
    0.849_367_613_732_569_97,
    0.896_321_155_766_052_1,
    0.934_906_075_937_739_7,
    0.964_762_255_587_506_4,
    0.985_611_511_545_268_3,
    0.997_263_861_849_481_56,
];
const GL32_W: [f64; 16] = [
    0.096_540_088_514_727_8,
    0.095_638_720_079_274_86,
    0.093_844_399_080_804_57,
    0.091_173_878_695_763_88,
    0.087_652_093_004_403_81,
    0.083_311_924_226_946_75,
    0.078_193_895_787_070_3,
    0.072_345_794_108_848_51,
    0.065_822_222_776_361_85,
    0.058_684_093_478_535_55,
    0.050_998_059_262_376_18,
    0.042_835_898_022_226_68,
    0.034_273_862_913_021_43,
    0.025_392_065_309_262_06,
    0.016_274_394_730_905_67,
    0.007_018_610_009_470_1,
];

/// Integrate f over [a, b] with the 32-point Gauss-Legendre rule.
pub fn gauss_legendre_32<F: FnMut(f64) -> f64>(a: f64, b: f64, mut f: F) -> f64 {
    if a == b {
        return 0.0;
    }
    let c = 0.5 * (a + b);
    let h = 0.5 * (b - a);
    let mut s = 0.0;
    for i in 0..16 {
        let dx = h * GL32_X[i];
        s += GL32_W[i] * (f(c + dx) + f(c - dx));
    }
    s * h
}

/// Composite GL32 over `panels` equal sub-intervals (for kinky integrands).
pub fn gauss_legendre_composite<F: FnMut(f64) -> f64>(
    a: f64,
    b: f64,
    panels: usize,
    mut f: F,
) -> f64 {
    assert!(panels >= 1);
    let h = (b - a) / panels as f64;
    (0..panels)
        .map(|i| gauss_legendre_32(a + i as f64 * h, a + (i + 1) as f64 * h, &mut f))
        .sum()
}

/// Adaptive Simpson with an absolute tolerance.
pub fn adaptive_simpson<F: FnMut(f64) -> f64>(a: f64, b: f64, tol: f64, mut f: F) -> f64 {
    fn simpson(fa: f64, fm: f64, fb: f64, a: f64, b: f64) -> f64 {
        (b - a) / 6.0 * (fa + 4.0 * fm + fb)
    }
    fn recurse<F: FnMut(f64) -> f64>(
        f: &mut F,
        a: f64,
        b: f64,
        fa: f64,
        fm: f64,
        fb: f64,
        whole: f64,
        tol: f64,
        depth: usize,
    ) -> f64 {
        let m = 0.5 * (a + b);
        let lm = 0.5 * (a + m);
        let rm = 0.5 * (m + b);
        let flm = f(lm);
        let frm = f(rm);
        let left = simpson(fa, flm, fm, a, m);
        let right = simpson(fm, frm, fb, m, b);
        let delta = left + right - whole;
        if depth == 0 || delta.abs() <= 15.0 * tol {
            left + right + delta / 15.0
        } else {
            recurse(f, a, m, fa, flm, fm, left, 0.5 * tol, depth - 1)
                + recurse(f, m, b, fm, frm, fb, right, 0.5 * tol, depth - 1)
        }
    }
    let m = 0.5 * (a + b);
    let fa = f(a);
    let fm = f(m);
    let fb = f(b);
    let whole = simpson(fa, fm, fb, a, b);
    recurse(&mut f, a, b, fa, fm, fb, whole, tol, 40)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gl32_polynomial_exact() {
        // GL32 is exact for polynomials up to degree 63.
        let got = gauss_legendre_32(0.0, 1.0, |x| x.powi(10));
        assert!((got - 1.0 / 11.0).abs() < 1e-14);
        let got = gauss_legendre_32(-2.0, 3.0, |x| 3.0 * x * x - x + 1.0);
        let want = (3.0f64.powi(3) - (-2.0f64).powi(3)) - (9.0 - 4.0) / 2.0 + 5.0;
        assert!((got - want).abs() < 1e-12);
    }

    #[test]
    fn gl32_transcendental() {
        let got = gauss_legendre_32(0.0, std::f64::consts::PI, f64::sin);
        assert!((got - 2.0).abs() < 1e-12);
        let got = gauss_legendre_32(0.0, 1.0, |x| (-x).exp());
        assert!((got - (1.0 - (-1.0f64).exp())).abs() < 1e-13);
    }

    #[test]
    fn composite_handles_kinks() {
        // |x - 0.3| has a kink; composite with enough panels converges.
        let f = |x: f64| (x - 0.3).abs();
        let want = 0.3f64.powi(2) / 2.0 + 0.7f64.powi(2) / 2.0;
        let got = gauss_legendre_composite(0.0, 1.0, 64, f);
        assert!((got - want).abs() < 1e-6, "got {got} want {want}");
    }

    #[test]
    fn adaptive_simpson_matches_gl() {
        let f = |x: f64| (5.0 * x).sin() * (-x * x).exp();
        let a = adaptive_simpson(-1.0, 2.0, 1e-12, f);
        let b = gauss_legendre_composite(-1.0, 2.0, 8, f);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn adaptive_simpson_sharp_peak() {
        // Narrow Gaussian: integral over wide interval ~ sqrt(pi)*w
        let w = 1e-3;
        let f = |x: f64| (-(x / w) * (x / w)).exp();
        let got = adaptive_simpson(-1.0, 1.0, 1e-12, f);
        let want = std::f64::consts::PI.sqrt() * w;
        assert!((got / want - 1.0).abs() < 1e-6, "got {got:e} want {want:e}");
    }

    #[test]
    fn zero_width_interval() {
        assert_eq!(gauss_legendre_32(0.5, 0.5, |x| x), 0.0);
    }
}

//! Test harnesses: a minimal property tester (offline substitute for
//! proptest) and a statistical-validation toolkit for checking that
//! sampler output actually targets the posterior it claims to.
//!
//! * `forall` — seeded case generation, a fixed case budget, and
//!   first-failure reporting with the seed so any failure is
//!   reproducible by construction (see DESIGN.md §Substitutions);
//! * `validate` — chi-square goodness-of-fit of histogrammed samples
//!   against an analytic CDF, and z-score moment checks, both with
//!   deterministic seeded thresholds;
//! * `models` — analytically solvable targets (the conjugate Gaussian
//!   mean model) to validate acceptance rules end to end;
//! * `fault` — scripted fault injection: compute faults (`FaultyModel`)
//!   exercising panic isolation, supervised retry and the
//!   numerical-guard layer, and checkpoint I/O faults (`FaultyStore`)
//!   — torn writes, bit flips, short reads, ENOSPC — exercising the
//!   CRC-sealed generation fallback.
//!
//! ```ignore
//! forall(128, |rng| {
//!     let n = rng.below(100) + 1;
//!     // ... build inputs from rng, assert the invariant ...
//! });
//! ```

use crate::stats::rng::Pcg64;

/// Default number of generated cases per property.
pub const DEFAULT_CASES: usize = 128;

/// Run `prop` against `cases` seeded RNGs; panics with the failing seed.
pub fn forall<F: FnMut(&mut Pcg64)>(cases: usize, mut prop: F) {
    for case in 0..cases {
        let seed = 0x5eed_0000u64 + case as u64;
        let mut rng = Pcg64::new(seed, 77);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// `forall` with the default case budget.
pub fn forall_default<F: FnMut(&mut Pcg64)>(prop: F) {
    forall(DEFAULT_CASES, prop)
}

/// Generator helpers for common shapes of random test input.
pub mod gen {
    use crate::stats::rng::Pcg64;

    /// Uniform f64 in [lo, hi).
    pub fn in_range(rng: &mut Pcg64, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * rng.uniform()
    }

    /// Size in [1, max].
    pub fn size(rng: &mut Pcg64, max: usize) -> usize {
        rng.below(max) + 1
    }

    /// Vector of standard normals.
    pub fn normal_vec(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(rng: &mut Pcg64, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| in_range(rng, lo, hi)).collect()
    }

    /// Random subset mask with inclusion probability p (at least 1 kept).
    pub fn mask(rng: &mut Pcg64, n: usize, p: f64) -> Vec<bool> {
        let mut m: Vec<bool> = (0..n).map(|_| rng.uniform() < p).collect();
        if !m.iter().any(|&b| b) {
            let i = rng.below(n);
            m[i] = true;
        }
        m
    }
}

/// Statistical validation: exact-chain-vs-analytic-posterior checks.
pub mod validate {
    use crate::stats::gamma::chi2_sf;
    use crate::stats::welford::Welford;
    use crate::stats::Histogram;

    /// Result of a chi-square goodness-of-fit test.
    #[derive(Clone, Copy, Debug)]
    pub struct GofReport {
        pub stat: f64,
        pub dof: usize,
        pub p_value: f64,
        /// Cells after merging low-expectation bins.
        pub cells: usize,
    }

    /// Pearson chi-square of a histogram against an analytic CDF.
    ///
    /// Edge bins absorb the tail mass (mirroring `Histogram`'s clamping
    /// of out-of-range samples), and adjacent bins are merged until each
    /// cell expects at least 5 counts — the usual validity rule. The
    /// p-value assumes (near-)independent draws; thin MCMC output until
    /// autocorrelation is negligible before testing, or divide the
    /// counts' weight by the integrated autocorrelation time.
    pub fn chi_square_hist<F: Fn(f64) -> f64>(h: &Histogram, cdf: F) -> GofReport {
        let total = h.total() as f64;
        assert!(total > 0.0, "empty histogram");
        let bins = h.bins();
        let w = h.bin_width();
        let mut expected = Vec::with_capacity(bins);
        for i in 0..bins {
            let lo = if i == 0 { 0.0 } else { cdf(h.center(i) - 0.5 * w) };
            let hi = if i == bins - 1 { 1.0 } else { cdf(h.center(i) + 0.5 * w) };
            expected.push((hi - lo).max(0.0) * total);
        }
        // merge forward until every cell expects >= 5 counts; fold any
        // leftover tail into the final cell
        let mut merged: Vec<(f64, f64)> = Vec::new();
        let (mut o, mut e) = (0.0, 0.0);
        for i in 0..bins {
            o += h.count(i) as f64;
            e += expected[i];
            if e >= 5.0 {
                merged.push((o, e));
                o = 0.0;
                e = 0.0;
            }
        }
        if e > 0.0 || o > 0.0 {
            if let Some(last) = merged.last_mut() {
                last.0 += o;
                last.1 += e;
            } else {
                merged.push((o, e));
            }
        }
        assert!(
            merged.len() >= 2,
            "chi-square needs >= 2 cells with expected mass; got {} (histogram range too wide?)",
            merged.len()
        );
        let stat: f64 = merged.iter().map(|&(o, e)| (o - e) * (o - e) / e).sum();
        let dof = merged.len() - 1;
        GofReport { stat, dof, p_value: chi2_sf(stat, dof as f64), cells: merged.len() }
    }

    /// z-scores of the accumulated sample mean and variance against an
    /// analytic `N(mean, var)` target. `n_eff` is the effective sample
    /// size — pass `w.n()` for independent draws, or the ESS for
    /// autocorrelated MCMC output.
    #[derive(Clone, Copy, Debug)]
    pub struct MomentReport {
        pub mean_z: f64,
        pub var_z: f64,
        pub n_eff: f64,
    }

    pub fn moment_z(w: &Welford, mean: f64, var: f64, n_eff: f64) -> MomentReport {
        assert!(var > 0.0 && n_eff > 1.0);
        let mean_z = (w.mean() - mean) / (var / n_eff).sqrt();
        // Var(s^2) = 2 sigma^4 / (n - 1) for Gaussian samples
        let var_z = (w.var_sample() - var) / (var * (2.0 / (n_eff - 1.0)).sqrt());
        MomentReport { mean_z, var_z, n_eff }
    }
}

/// Analytically solvable targets for end-to-end sampler validation.
pub mod models {
    use crate::models::traits::{LlDiffModel, Proposal};
    use crate::stats::normal::phi_cdf;
    use crate::stats::Pcg64;

    /// Conjugate Gaussian mean model: `x_i ~ N(theta, noise_var)` with a
    /// `N(prior_mean, prior_var)` prior on `theta`, so the posterior is
    /// Gaussian in closed form — the reference target of the
    /// statistical-validation tests.
    pub struct ConjugateGaussian {
        xs: Vec<f64>,
        pub noise_var: f64,
        pub prior_mean: f64,
        pub prior_var: f64,
    }

    impl ConjugateGaussian {
        pub fn new(xs: Vec<f64>, noise_var: f64, prior_mean: f64, prior_var: f64) -> Self {
            assert!(!xs.is_empty() && noise_var > 0.0 && prior_var > 0.0);
            ConjugateGaussian { xs, noise_var, prior_mean, prior_var }
        }

        /// Seeded synthetic dataset of `n` points at `true_mean`.
        pub fn synthetic(
            n: usize,
            true_mean: f64,
            noise_sd: f64,
            prior_mean: f64,
            prior_sd: f64,
            seed: u64,
        ) -> Self {
            let mut rng = Pcg64::new(seed, 17);
            let xs = (0..n).map(|_| true_mean + noise_sd * rng.normal()).collect();
            Self::new(xs, noise_sd * noise_sd, prior_mean, prior_sd * prior_sd)
        }

        pub fn posterior_var(&self) -> f64 {
            1.0 / (1.0 / self.prior_var + self.xs.len() as f64 / self.noise_var)
        }

        pub fn posterior_mean(&self) -> f64 {
            let sum: f64 = self.xs.iter().sum();
            self.posterior_var() * (self.prior_mean / self.prior_var + sum / self.noise_var)
        }

        pub fn posterior_cdf(&self, x: f64) -> f64 {
            phi_cdf((x - self.posterior_mean()) / self.posterior_var().sqrt())
        }

        /// Symmetric random-walk proposal with the prior folded into
        /// `log_correction` (`log rho(cur) - log rho(prop)`).
        pub fn rw_proposal(&self, sigma: f64) -> impl Fn(&f64, &mut Pcg64) -> Proposal<f64> + Sync {
            let (m, v) = (self.prior_mean, self.prior_var);
            move |cur: &f64, rng: &mut Pcg64| {
                let prop = cur + sigma * rng.normal();
                let log_correction = ((prop - m) * (prop - m) - (cur - m) * (cur - m)) / (2.0 * v);
                Proposal { param: prop, log_correction }
            }
        }
    }

    impl LlDiffModel for ConjugateGaussian {
        type Param = f64;

        fn n(&self) -> usize {
            self.xs.len()
        }

        fn lldiff(&self, i: usize, cur: &f64, prop: &f64) -> f64 {
            let x = self.xs[i];
            let (rc, rp) = (x - cur, x - prop);
            (rc * rc - rp * rp) / (2.0 * self.noise_var)
        }
    }

    impl crate::models::traits::ShardableModel for ConjugateGaussian {
        /// Shard `shard` keeps its even row range of the observations
        /// with the hyper-parameters unchanged (the 1/shards prior
        /// tempering lives in the proposal's `log_correction`, applied
        /// by `Session::run_sharded`).
        fn shard_model(
            &self,
            shard: usize,
            shards: usize,
        ) -> Result<Self, crate::data::DataTooLarge> {
            let (start, end) = crate::data::sharded::even_rows(self.xs.len(), shard, shards);
            Ok(ConjugateGaussian {
                xs: self.xs[start..end].to_vec(),
                noise_var: self.noise_var,
                prior_mean: self.prior_mean,
                prior_var: self.prior_var,
            })
        }
    }
}

/// Scripted fault injection for the fault-tolerance tests: compute
/// faults ([`fault::FaultyModel`]) and checkpoint I/O faults
/// ([`fault::FaultyStore`]), both deterministic by construction.
pub mod fault {
    use std::path::Path;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    use crate::coordinator::chain::current_chain_step;
    use crate::coordinator::checkpoint::{fs_store, StoreLayer};
    use crate::models::traits::{LlDiffModel, ShardableModel};

    /// What a scripted fault point injects when reached.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum FaultKind {
        /// Panic inside the likelihood evaluation (worker crash).
        Panic,
        /// Return NaN moments (silent numerical poisoning).
        Nan,
        /// Return +Inf moments.
        Inf,
    }

    /// One scripted compute-fault point.
    #[derive(Debug)]
    struct Fault {
        /// Restrict to this shard's model (`None` = any shard).
        shard: Option<usize>,
        chain: usize,
        step: usize,
        kind: FaultKind,
        /// Fire only on the first hit — the supervised-retry scenario: a
        /// chain crashes once, then its restarted attempt replays clean.
        once: bool,
        fired: AtomicBool,
    }

    /// Wraps any `LlDiffModel` and fires scripted faults when the
    /// executing chain reaches a scheduled step, identified through the
    /// drive loop's thread-local chain/step context
    /// (`coordinator::chain::current_chain_step`). Every unscheduled
    /// evaluation delegates to the inner model untouched, so a
    /// fault-free `FaultyModel` run is bit-identical to the bare model.
    ///
    /// Faults scheduled with [`FaultyModel::fault`] fire on every hit
    /// (a chain that retries into the same step crashes again);
    /// [`FaultyModel::fault_once`] arms a one-shot fault so a supervised
    /// retry replays past it. Fault state is shared across
    /// [`ShardableModel::shard_model`] clones, and
    /// [`FaultyModel::fault_on`] targets a single shard.
    pub struct FaultyModel<M> {
        inner: M,
        shard: Option<usize>,
        faults: Vec<Arc<Fault>>,
    }

    impl<M> FaultyModel<M> {
        pub fn new(inner: M) -> Self {
            FaultyModel { inner, shard: None, faults: Vec::new() }
        }

        /// Schedule `kind` to fire whenever `chain` executes step `step`
        /// (every attempt — a retried chain crashes again).
        pub fn fault(mut self, chain: usize, step: usize, kind: FaultKind) -> Self {
            self.faults.push(Arc::new(Fault {
                shard: None,
                chain,
                step,
                kind,
                once: false,
                fired: AtomicBool::new(false),
            }));
            self
        }

        /// Schedule `kind` to fire the *first* time `chain` executes
        /// step `step`; subsequent hits (a supervised retry replaying
        /// from checkpoint) pass through clean.
        pub fn fault_once(mut self, chain: usize, step: usize, kind: FaultKind) -> Self {
            self.faults.push(Arc::new(Fault {
                shard: None,
                chain,
                step,
                kind,
                once: true,
                fired: AtomicBool::new(false),
            }));
            self
        }

        /// Schedule `kind` on shard `shard`'s model only (for
        /// `run_sharded` launches; fires every attempt).
        pub fn fault_on(mut self, shard: usize, chain: usize, step: usize, kind: FaultKind) -> Self {
            self.faults.push(Arc::new(Fault {
                shard: Some(shard),
                chain,
                step,
                kind,
                once: false,
                fired: AtomicBool::new(false),
            }));
            self
        }

        fn active(&self) -> Option<FaultKind> {
            let (chain, step) = current_chain_step();
            for f in &self.faults {
                if f.chain != chain || f.step != step {
                    continue;
                }
                if let Some(s) = f.shard {
                    if self.shard != Some(s) {
                        continue;
                    }
                }
                if f.once && f.fired.swap(true, Ordering::Relaxed) {
                    continue;
                }
                return Some(f.kind);
            }
            None
        }

        fn poison(kind: FaultKind) -> (f64, f64) {
            match kind {
                FaultKind::Panic => panic!("injected fault: scripted panic in likelihood"),
                FaultKind::Nan => (f64::NAN, f64::NAN),
                FaultKind::Inf => (f64::INFINITY, f64::INFINITY),
            }
        }
    }

    impl<M: LlDiffModel> LlDiffModel for FaultyModel<M> {
        type Param = M::Param;

        fn n(&self) -> usize {
            self.inner.n()
        }

        fn lldiff(&self, i: usize, cur: &M::Param, prop: &M::Param) -> f64 {
            match self.active() {
                Some(kind) => Self::poison(kind).0,
                None => self.inner.lldiff(i, cur, prop),
            }
        }

        fn lldiff_moments(&self, idx: &[u32], cur: &M::Param, prop: &M::Param) -> (f64, f64) {
            match self.active() {
                Some(kind) => Self::poison(kind),
                None => self.inner.lldiff_moments(idx, cur, prop),
            }
        }

        fn lldiff_range_moments(
            &self,
            start: usize,
            end: usize,
            cur: &M::Param,
            prop: &M::Param,
        ) -> (f64, f64) {
            match self.active() {
                Some(kind) => Self::poison(kind),
                None => self.inner.lldiff_range_moments(start, end, cur, prop),
            }
        }
    }

    impl<M: ShardableModel> ShardableModel for FaultyModel<M> {
        fn shard_model(
            &self,
            shard: usize,
            shards: usize,
        ) -> Result<Self, crate::data::DataTooLarge> {
            Ok(FaultyModel {
                inner: self.inner.shard_model(shard, shards)?,
                shard: Some(shard),
                // shared Arc state: a one-shot fault fires once across
                // the whole sharded launch, not once per shard clone
                faults: self.faults.clone(),
            })
        }
    }

    /// What a scripted [`FaultyStore`] point does to the checkpoint I/O
    /// it intercepts.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum StoreFault {
        /// Torn write: persist only the first `k` bytes, report success
        /// (the crash-after-partial-flush a rename cannot save you from
        /// when the tear happens before the rename source is complete).
        TruncateAt(usize),
        /// Fail the write with an out-of-space I/O error.
        Enospc,
        /// Flip one bit of byte `offset` on read (silent media
        /// corruption; the CRC trailer must catch it).
        FlipBit(usize),
        /// Return only the first `k` bytes on read (short read).
        ShortRead(usize),
    }

    impl StoreFault {
        fn applies_to_write(self) -> bool {
            matches!(self, StoreFault::TruncateAt(_) | StoreFault::Enospc)
        }
    }

    /// One scripted I/O-fault point, keyed to an exact
    /// `(chain, generation)` checkpoint file. One-shot: it fires on the
    /// first matching operation and then disarms, so a rotated retry or
    /// a fallback load observes the fault exactly once.
    #[derive(Debug)]
    struct StoreScript {
        chain: usize,
        generation: u64,
        fault: StoreFault,
        fired: AtomicBool,
    }

    /// A [`StoreLayer`] wrapper scripting checkpoint I/O faults at exact
    /// `(chain, generation)` points — the disk-side mirror of
    /// [`FaultyModel`]'s compute faults. Paths that are not generation
    /// files (the manifest, foreign files) and unscheduled operations
    /// delegate to the wrapped store untouched. Install it with
    /// `Session::checkpoint_store(store.into_arc())`.
    #[derive(Debug)]
    pub struct FaultyStore {
        inner: Arc<dyn StoreLayer>,
        scripts: Vec<StoreScript>,
    }

    impl Default for FaultyStore {
        fn default() -> Self {
            Self::new()
        }
    }

    impl FaultyStore {
        /// Script over the production filesystem store.
        pub fn new() -> Self {
            FaultyStore { inner: fs_store(), scripts: Vec::new() }
        }

        /// Schedule `fault` on chain `chain`'s generation-`generation`
        /// checkpoint file (first matching operation only).
        pub fn fault(mut self, chain: usize, generation: u64, fault: StoreFault) -> Self {
            self.scripts.push(StoreScript {
                chain,
                generation,
                fault,
                fired: AtomicBool::new(false),
            });
            self
        }

        /// Finish scripting: the `Arc<dyn StoreLayer>` the session/engine
        /// builders take.
        pub fn into_arc(self) -> Arc<dyn StoreLayer> {
            Arc::new(self)
        }

        /// The armed script matching `path` for a write (`write`) or
        /// read operation, consuming its one shot.
        fn take(&self, path: &Path, write: bool) -> Option<StoreFault> {
            let name = path.file_name()?.to_str()?;
            let (chain, generation) =
                crate::coordinator::checkpoint::parse_gen_name(name)?;
            for s in &self.scripts {
                if s.chain == chain
                    && s.generation == generation
                    && s.fault.applies_to_write() == write
                    && !s.fired.swap(true, Ordering::Relaxed)
                {
                    return Some(s.fault);
                }
            }
            None
        }
    }

    impl StoreLayer for FaultyStore {
        fn read(&self, path: &Path) -> std::io::Result<Vec<u8>> {
            let mut bytes = self.inner.read(path)?;
            match self.take(path, false) {
                Some(StoreFault::FlipBit(offset)) => {
                    if let Some(b) = bytes.get_mut(offset) {
                        *b ^= 0x01;
                    }
                }
                Some(StoreFault::ShortRead(k)) => bytes.truncate(k),
                _ => {}
            }
            Ok(bytes)
        }

        fn write_atomic(&self, path: &Path, bytes: &[u8]) -> std::io::Result<()> {
            match self.take(path, true) {
                Some(StoreFault::TruncateAt(k)) => {
                    self.inner.write_atomic(path, &bytes[..k.min(bytes.len())])
                }
                Some(StoreFault::Enospc) => Err(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    "injected: no space left on device (ENOSPC)",
                )),
                _ => self.inner.write_atomic(path, bytes),
            }
        }

        fn remove(&self, path: &Path) -> std::io::Result<()> {
            self.inner.remove(path)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(32, |rng| {
            let a = rng.uniform();
            assert!((0.0..1.0).contains(&a));
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn failing_property_reports_case() {
        forall(32, |rng| {
            // Fails for roughly half the cases; harness reports the first.
            assert!(rng.uniform() < 0.5);
        });
    }

    #[test]
    fn generators_respect_bounds() {
        forall(64, |rng| {
            let n = gen::size(rng, 50);
            assert!((1..=50).contains(&n));
            let v = gen::uniform_vec(rng, n, -2.0, 3.0);
            assert_eq!(v.len(), n);
            assert!(v.iter().all(|&x| (-2.0..3.0).contains(&x)));
            let m = gen::mask(rng, n, 0.3);
            assert!(m.iter().any(|&b| b));
        });
    }

    #[test]
    fn chi_square_accepts_the_true_distribution() {
        let mut rng = Pcg64::seeded(0);
        let mut h = crate::stats::Histogram::new(-4.0, 4.0, 32);
        for _ in 0..20_000 {
            h.add(rng.normal());
        }
        let rep = validate::chi_square_hist(&h, crate::stats::normal::phi_cdf);
        assert!(rep.p_value > 1e-4, "{rep:?}");
        assert!(rep.cells >= 10 && rep.dof == rep.cells - 1, "{rep:?}");
    }

    #[test]
    fn chi_square_rejects_a_shifted_distribution() {
        let mut rng = Pcg64::seeded(1);
        let mut h = crate::stats::Histogram::new(-4.0, 4.0, 32);
        for _ in 0..20_000 {
            h.add(0.15 + rng.normal());
        }
        let rep =
            validate::chi_square_hist(&h, crate::stats::normal::phi_cdf);
        assert!(rep.p_value < 1e-6, "a 0.15-sigma shift must be detected: {rep:?}");
    }

    #[test]
    fn moment_z_scores_are_calibrated() {
        let mut rng = Pcg64::seeded(2);
        let mut w = crate::stats::Welford::new();
        for _ in 0..50_000 {
            w.add(2.0 + 0.5 * rng.normal());
        }
        let rep = validate::moment_z(&w, 2.0, 0.25, w.n() as f64);
        assert!(rep.mean_z.abs() < 4.0, "{rep:?}");
        assert!(rep.var_z.abs() < 4.0, "{rep:?}");
        // a wrong variance target must blow up the z-score
        let bad = validate::moment_z(&w, 2.0, 0.30, w.n() as f64);
        assert!(bad.var_z.abs() > 10.0, "{bad:?}");
    }

    #[test]
    fn conjugate_gaussian_posterior_closed_form() {
        let m = models::ConjugateGaussian::new(vec![1.0, 3.0], 2.0, 0.0, 8.0);
        // precision = 1/8 + 2/2 = 1.125; mean = (0 + 4/2) / 1.125
        assert!((m.posterior_var() - 1.0 / 1.125).abs() < 1e-12);
        assert!((m.posterior_mean() - 2.0 / 1.125).abs() < 1e-12);
        assert!((m.posterior_cdf(m.posterior_mean()) - 0.5).abs() < 1e-12);
        // lldiff really is the pointwise log-likelihood difference
        use crate::models::traits::LlDiffModel;
        let ll = |x: f64, t: f64| -(x - t) * (x - t) / (2.0 * 2.0);
        let want = ll(1.0, 0.7) - ll(1.0, 0.2);
        assert!((m.lldiff(0, &0.2, &0.7) - want).abs() < 1e-12);
    }

    #[test]
    fn faulty_model_delegates_when_no_fault_is_scheduled_here() {
        use crate::models::traits::LlDiffModel;
        let inner = models::ConjugateGaussian::new(vec![1.0, 3.0], 2.0, 0.0, 8.0);
        let want = inner.lldiff(0, &0.2, &0.7);
        let m = fault::FaultyModel::new(inner).fault(0, 5, fault::FaultKind::Nan);
        // outside a drive loop the chain/step context is unset, so the
        // scripted point never matches and the wrapper is transparent
        assert_eq!(m.lldiff(0, &0.2, &0.7), want);
        let (s, s2) = m.lldiff_moments(&[0, 1], &0.2, &0.7);
        assert!(s.is_finite() && s2.is_finite());
        assert_eq!(m.n(), 2);
    }

    #[test]
    fn conjugate_gaussian_correction_is_prior_ratio() {
        let m = models::ConjugateGaussian::synthetic(50, 1.0, 1.0, 0.5, 3.0, 9);
        let kernel = m.rw_proposal(0.3);
        let mut rng = Pcg64::seeded(4);
        let p = crate::models::traits::ProposalKernel::propose(&kernel, &1.2, &mut rng);
        let lp = |t: f64| -(t - 0.5) * (t - 0.5) / (2.0 * 9.0);
        let want = lp(1.2) - lp(p.param);
        assert!((p.log_correction - want).abs() < 1e-12);
    }
}

//! Minimal property-testing harness (offline substitute for proptest).
//!
//! The vendored crate set does not include proptest, so invariants are
//! checked with this deterministic mini-harness: seeded case generation,
//! a fixed case budget, and first-failure reporting with the seed so any
//! failure is reproducible by construction. See DESIGN.md §Substitutions.
//!
//! ```ignore
//! forall(128, |rng| {
//!     let n = rng.below(100) + 1;
//!     // ... build inputs from rng, assert the invariant ...
//! });
//! ```

use crate::stats::rng::Pcg64;

/// Default number of generated cases per property.
pub const DEFAULT_CASES: usize = 128;

/// Run `prop` against `cases` seeded RNGs; panics with the failing seed.
pub fn forall<F: FnMut(&mut Pcg64)>(cases: usize, mut prop: F) {
    for case in 0..cases {
        let seed = 0x5eed_0000u64 + case as u64;
        let mut rng = Pcg64::new(seed, 77);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// `forall` with the default case budget.
pub fn forall_default<F: FnMut(&mut Pcg64)>(prop: F) {
    forall(DEFAULT_CASES, prop)
}

/// Generator helpers for common shapes of random test input.
pub mod gen {
    use crate::stats::rng::Pcg64;

    /// Uniform f64 in [lo, hi).
    pub fn in_range(rng: &mut Pcg64, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * rng.uniform()
    }

    /// Size in [1, max].
    pub fn size(rng: &mut Pcg64, max: usize) -> usize {
        rng.below(max) + 1
    }

    /// Vector of standard normals.
    pub fn normal_vec(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(rng: &mut Pcg64, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| in_range(rng, lo, hi)).collect()
    }

    /// Random subset mask with inclusion probability p (at least 1 kept).
    pub fn mask(rng: &mut Pcg64, n: usize, p: f64) -> Vec<bool> {
        let mut m: Vec<bool> = (0..n).map(|_| rng.uniform() < p).collect();
        if !m.iter().any(|&b| b) {
            let i = rng.below(n);
            m[i] = true;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(32, |rng| {
            let a = rng.uniform();
            assert!((0.0..1.0).contains(&a));
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn failing_property_reports_case() {
        forall(32, |rng| {
            // Fails for roughly half the cases; harness reports the first.
            assert!(rng.uniform() < 0.5);
        });
    }

    #[test]
    fn generators_respect_bounds() {
        forall(64, |rng| {
            let n = gen::size(rng, 50);
            assert!((1..=50).contains(&n));
            let v = gen::uniform_vec(rng, n, -2.0, 3.0);
            assert_eq!(v.len(), n);
            assert!(v.iter().all(|&x| (-2.0..3.0).contains(&x)));
            let m = gen::mask(rng, n, 0.3);
            assert!(m.iter().any(|&b| b));
        });
    }
}

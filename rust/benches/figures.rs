//! Figure-regeneration bench (`cargo bench --bench figures`): runs every
//! paper-figure driver at a reduced scale, timing each, and prints the
//! headline shape checks. Full-scale runs: `austerity fig all --scale 1`.
//!
//! Plain binary (criterion is not in the offline crate set); scale can be
//! overridden with AUSTERITY_BENCH_SCALE (default 0.08).

use austerity::exp::{run_figure, Scale, ALL_FIGURES};

fn main() {
    // `cargo bench -- --quick` style filtering: any args = figure names
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let scale = Scale(
        std::env::var("AUSTERITY_BENCH_SCALE")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0.08),
    );
    let names: Vec<&str> = if args.is_empty() {
        ALL_FIGURES.to_vec()
    } else {
        ALL_FIGURES
            .iter()
            .copied()
            .filter(|n| args.iter().any(|a| a == n))
            .collect()
    };

    println!("figure bench at scale {} (AUSTERITY_BENCH_SCALE to change)", scale.0);
    let total = std::time::Instant::now();
    for name in names {
        let t0 = std::time::Instant::now();
        let ok = run_figure(name, scale);
        assert!(ok, "unknown figure {name}");
        println!("== {name} done in {:.1}s ==\n", t0.elapsed().as_secs_f64());
    }
    println!(
        "all figures regenerated in {:.1}s; CSVs under {}",
        total.elapsed().as_secs_f64(),
        austerity::exp::figures_dir().display()
    );
}

//! Hot-path micro-benchmarks (`cargo bench --bench hotpath`): the
//! components on the per-MH-step critical path, timed with a simple
//! median-of-runs harness (criterion is not in the offline crate set).
//!
//! Layers:
//!   L3 moments kernels  — naive per-index loop vs the retained
//!                         row-major reference vs the lane-blocked SoA
//!                         kernels (gathered + cached)
//!   L3 SoA @ 50k        — the acceptance workload: SoA vs row-major
//!                         reference on a logistic N = 50k population
//!                         (`speedup_soa_vs_fused_x`), plus the
//!                         deterministic parallel exact scan at 1 and 4
//!                         workers (`full_scan_par_t{1,4}`), the same
//!                         scan on the persistent executor
//!                         (`executor_scan_t{1,4}`) against a per-call
//!                         `thread::scope` baseline
//!                         (`executor_vs_scope_speedup_x`), 4
//!                         concurrent sessions sharing the global pool
//!                         (`executor_many_sessions_sps`), and the same
//!                         scan over an 8-way sharded store
//!                         (`shard_scan_t{1,4}`, `shard_scaling_x`)
//!                         checked bit-identical to the monolithic
//!                         store
//!   L3 sequential test  — one full approximate MH decision
//!   L3 mh_step          — end-to-end step, uncached vs cached
//!   L3 engine           — K-chain throughput scaling on the worker pool
//!   L3 substrate        — t-CDF, scheduler, DP
//!   L1/L2 via PJRT      — the AOT Pallas kernel executed through PJRT
//!
//! Besides the human-readable table, every measurement lands in
//! `BENCH_hotpath.json` (name -> median ns unless the key says
//! otherwise), so the perf trajectory is tracked PR over PR.

use std::time::Instant;

use austerity::coordinator::austerity::{seq_mh_test, SeqTestConfig};
use austerity::coordinator::dp::analyze_pocock;
use austerity::coordinator::scheduler::MinibatchScheduler;
use austerity::coordinator::{
    mh_step, mh_step_cached, Budget, Executor, KernelSession, MhMode, MhScratch, RetryPolicy,
    ScalarFn, Session,
};
use austerity::data::synthetic::linreg_toy;
use austerity::models::traits::{
    full_scan_moments_par, CachedLlDiff, LlDiffModel, ProposalKernel, ScanScratch,
    FULL_SCAN_CHUNK,
};
use austerity::models::{LinRegModel, MrfModel};
use austerity::runtime::{PjrtLogistic, PjrtRuntime};
use austerity::samplers::gibbs::{GibbsMode, GibbsSweepKernel};
use austerity::samplers::sgld::{SgldConfig, SgldKernel};
use austerity::stats::student_t::t_sf;
use austerity::stats::Pcg64;

/// Timing harness: records every measurement for the JSON report.
struct Recorder {
    rows: Vec<(String, f64)>,
}

impl Recorder {
    fn new() -> Self {
        Recorder { rows: Vec::new() }
    }

    /// Median wall time of `iters` calls, repeated 7 times; recorded in
    /// nanoseconds under `name`.
    fn bench<F: FnMut()>(&mut self, name: &str, iters: usize, mut f: F) -> f64 {
        for _ in 0..iters.div_ceil(4).max(1) {
            f();
        }
        let mut times: Vec<f64> = (0..7)
            .map(|_| {
                let t0 = Instant::now();
                for _ in 0..iters {
                    f();
                }
                t0.elapsed().as_secs_f64() / iters as f64
            })
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = times[3];
        let (val, unit) = if med < 1e-6 {
            (med * 1e9, "ns")
        } else if med < 1e-3 {
            (med * 1e6, "us")
        } else {
            (med * 1e3, "ms")
        };
        println!("{name:<44} {val:>9.2} {unit}/iter");
        self.rows.push((name.to_string(), med * 1e9));
        med
    }

    /// Record a derived, non-timing value (ratios, throughputs).
    fn record(&mut self, name: &str, value: f64) {
        self.rows.push((name.to_string(), value));
    }

    /// Minimal JSON object: {"name": value, ...}; no escaping needed as
    /// long as names stay [a-z0-9_].
    fn write_json(&self, path: &str) {
        let mut s = String::from("{\n");
        for (i, (k, v)) in self.rows.iter().enumerate() {
            s.push_str(&format!("  \"{k}\": {v:.3}"));
            if i + 1 < self.rows.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("}\n");
        std::fs::write(path, &s).expect("write bench json");
        println!("\nmachine-readable results -> {path}");
    }
}

/// The pre-executor span scan, kept verbatim as the baseline for
/// `executor_vs_scope_speedup_x`: partition chunks into one span per
/// worker, spawn a scoped thread per span *on every call*, reduce the
/// per-chunk partials in chunk-index order.
fn scoped_scan(
    n: usize,
    workers: usize,
    partials: &mut Vec<(f64, f64)>,
    eval: impl Fn(usize, usize) -> (f64, f64) + Sync,
) -> (f64, f64) {
    let n_chunks = n.div_ceil(FULL_SCAN_CHUNK);
    partials.clear();
    partials.resize(n_chunks, (0.0, 0.0));
    let workers = workers.min(n_chunks).max(1);
    std::thread::scope(|s| {
        let mut rest: &mut [(f64, f64)] = partials;
        let mut span_start = 0usize;
        for w in 0..workers {
            let len = n_chunks / workers + usize::from(w < n_chunks % workers);
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(len);
            rest = tail;
            let start = span_start;
            span_start += len * FULL_SCAN_CHUNK;
            let eval = &eval;
            s.spawn(move || {
                for (i, out) in head.iter_mut().enumerate() {
                    let a = start + i * FULL_SCAN_CHUNK;
                    let b = (a + FULL_SCAN_CHUNK).min(n);
                    *out = eval(a, b);
                }
            });
        }
    });
    let (mut m1, mut m2) = (0.0, 0.0);
    for &(a, b) in partials.iter() {
        m1 += a;
        m2 += b;
    }
    (m1, m2)
}

fn main() {
    let mut rec = Recorder::new();
    let n = 12_214usize;
    let model = austerity::exp::population::mnist_like_model(n, 42);
    let mut rng = Pcg64::seeded(0);
    let theta = model.map_estimate(60);
    let theta_p: Vec<f64> = theta.iter().map(|t| t + 0.01 * rng.normal()).collect();
    let idx: Vec<u32> = (0..500).map(|_| rng.below(n) as u32).collect();

    println!("\n-- L3 moments kernels (N = {n}, D = 50, m = 500) --");
    let t_naive = rec.bench("lldiff_moments_naive", 200, || {
        // the pre-fusion baseline: one `lldiff` call per index, two
        // unblocked dot products per row
        let (mut s, mut s2) = (0.0, 0.0);
        for &i in &idx {
            let l = model.lldiff(i as usize, &theta, &theta_p);
            s += l;
            s2 += l * l;
        }
        std::hint::black_box((s, s2));
    });
    // the retained row-major scalar reference (pre-SoA "fused" kernel)
    let t_fused = rec.bench("lldiff_moments_fused", 200, || {
        std::hint::black_box(model.lldiff_moments_ref(&idx, &theta, &theta_p));
    });
    // the production lane-blocked SoA kernel on the same minibatch
    let t_soa_batch = rec.bench("lldiff_moments_soa_batch", 200, || {
        std::hint::black_box(model.lldiff_moments(&idx, &theta, &theta_p));
    });
    let mut cache = model.init_cache(&theta);
    model.begin_step(&mut cache);
    let t_cached = rec.bench("lldiff_moments_cached", 200, || {
        std::hint::black_box(model.cached_moments(&mut cache, &idx, &theta_p));
    });
    println!(
        "{:<44} {:>9.2} Melem/s",
        "  -> soa batch throughput",
        500.0 * 50.0 / t_soa_batch / 1e6
    );
    let fused_speedup = t_naive / t_fused;
    let cached_speedup = t_naive / t_cached;
    rec.record("speedup_fused_vs_naive_x", fused_speedup);
    rec.record("speedup_cached_vs_naive_x", cached_speedup);
    println!(
        "  -> speedup vs naive: fused-ref {fused_speedup:.2}x, cached {cached_speedup:.2}x ({})",
        if cached_speedup >= 1.5 { "PASS >= 1.5x" } else { "FAIL < 1.5x" }
    );

    // -- the acceptance workload: logistic N = 50k ------------------------
    let n50 = 50_000usize;
    let big = austerity::exp::population::mnist_like_model(n50, 7);
    let theta50: Vec<f64> = (0..50).map(|_| 0.1 * rng.normal()).collect();
    let theta50_p: Vec<f64> = theta50.iter().map(|t| t + 0.01 * rng.normal()).collect();
    // the exact-scan work unit: one FULL_SCAN_CHUNK of consecutive rows
    let chunk: Vec<u32> = (0..FULL_SCAN_CHUNK as u32).collect();
    println!("\n-- L3 SoA kernels (N = {n50}, D = 50, chunk = {FULL_SCAN_CHUNK}) --");
    let t_fused50 = rec.bench("lldiff_moments_fused_50k", 200, || {
        std::hint::black_box(big.lldiff_moments_ref(&chunk, &theta50, &theta50_p));
    });
    let t_soa50 = rec.bench("lldiff_moments_soa", 200, || {
        std::hint::black_box(big.lldiff_range_moments(0, FULL_SCAN_CHUNK, &theta50, &theta50_p));
    });
    let mut cache50 = big.init_cache(&theta50);
    big.begin_step(&mut cache50);
    let t_soa50_cached = rec.bench("lldiff_moments_soa_cached", 200, || {
        std::hint::black_box(big.cached_moments(&mut cache50, &chunk, &theta50_p));
    });
    let soa_speedup = t_fused50 / t_soa50;
    let soa_cached_speedup = t_fused50 / t_soa50_cached;
    rec.record("speedup_soa_vs_fused_x", soa_speedup);
    rec.record("speedup_soa_cached_vs_fused_x", soa_cached_speedup);
    println!(
        "  -> SoA vs fused-ref: uncached {soa_speedup:.2}x, cached {soa_cached_speedup:.2}x ({})",
        if soa_speedup >= 1.5 { "PASS >= 1.5x" } else { "FAIL < 1.5x" }
    );

    // deterministic parallel exact scan, K = 1 chain with spare workers
    let mut t_scan = [0.0f64; 2];
    for (slot, threads) in [(0usize, 1usize), (1, 4)] {
        let mut scan = ScanScratch::new(threads, n50);
        let t = rec.bench(&format!("full_scan_par_t{threads}"), 20, || {
            std::hint::black_box(full_scan_moments_par(n50, &mut scan, |a, b| {
                big.lldiff_range_moments(a, b, &theta50, &theta50_p)
            }));
        });
        t_scan[slot] = t;
    }
    let scan_scaling = t_scan[0] / t_scan[1];
    rec.record("full_scan_par_scaling_x", scan_scaling);
    println!(
        "  -> parallel exact scan 1 -> 4 workers: {scan_scaling:.2}x ({})",
        if scan_scaling > 1.0 { "PASS > 1x" } else { "FAIL <= 1x" }
    );

    // the same scan through the persistent executor: spans are pool
    // tasks, zero thread spawns per call (3 workers + the helping
    // submitter = the same 4-way concurrency as above)
    let pool = Executor::new(3);
    let mut t_exec = [0.0f64; 2];
    for (slot, threads) in [(0usize, 1usize), (1, 4)] {
        let mut scan = ScanScratch::on_pool(&pool, threads, n50);
        let t = rec.bench(&format!("executor_scan_t{threads}"), 20, || {
            std::hint::black_box(full_scan_moments_par(n50, &mut scan, |a, b| {
                big.lldiff_range_moments(a, b, &theta50, &theta50_p)
            }));
        });
        t_exec[slot] = t;
    }
    rec.record("executor_scan_scaling_x", t_exec[0] / t_exec[1]);
    // per-call thread::scope baseline: same span partition, fresh OS
    // threads each scan — what the hot path paid before the executor
    let mut parts: Vec<(f64, f64)> = Vec::new();
    let t_scope4 = rec.bench("full_scan_scope_t4", 20, || {
        std::hint::black_box(scoped_scan(n50, 4, &mut parts, |a, b| {
            big.lldiff_range_moments(a, b, &theta50, &theta50_p)
        }));
    });
    let exec_speedup = t_scope4 / t_exec[1];
    rec.record("executor_vs_scope_speedup_x", exec_speedup);
    println!(
        "  -> executor vs per-step scope at 4 workers: {exec_speedup:.2}x ({})",
        if exec_speedup >= 1.0 { "PASS >= 1x" } else { "below 1x" }
    );

    // the same exact scan over an 8-way sharded store: segment
    // boundaries are FULL_SCAN_CHUNK-aligned, so every chunk stays
    // inside one segment and the reduction is bit-identical to the
    // monolithic store above
    let sharded = austerity::models::LogisticModel::with_shards(
        austerity::data::synthetic::two_class_gaussian(n50, 50, 1.2, 7),
        10.0,
        8,
    )
    .unwrap();
    let mut t_shard = [0.0f64; 2];
    for (slot, threads) in [(0usize, 1usize), (1, 4)] {
        let mut scan = ScanScratch::new(threads, n50);
        let t = rec.bench(&format!("shard_scan_t{threads}"), 20, || {
            std::hint::black_box(full_scan_moments_par(n50, &mut scan, |a, b| {
                sharded.lldiff_range_moments(a, b, &theta50, &theta50_p)
            }));
        });
        t_shard[slot] = t;
    }
    rec.record("shard_scaling_x", t_shard[0] / t_shard[1]);
    {
        let mut scan = ScanScratch::new(4, n50);
        let got = full_scan_moments_par(n50, &mut scan, |a, b| {
            sharded.lldiff_range_moments(a, b, &theta50, &theta50_p)
        });
        let want = full_scan_moments_par(n50, &mut scan, |a, b| {
            big.lldiff_range_moments(a, b, &theta50, &theta50_p)
        });
        let identical = got.0.to_bits() == want.0.to_bits() && got.1.to_bits() == want.1.to_bits();
        println!(
            "  -> sharded scan (8 segments) vs monolithic: {}",
            if identical { "PASS bit-identical" } else { "FAIL bits differ" }
        );
    }

    println!("\n-- L3 sequential test + steps --");
    let cfg = SeqTestConfig::new(0.05, 500);
    let mut sched = MinibatchScheduler::new(n).unwrap();
    rec.bench("seq_mh_test", 100, || {
        let mu0 = (rng.uniform_pos().ln()) / n as f64;
        std::hint::black_box(seq_mh_test(&model, &theta, &theta_p, mu0, &cfg, &mut sched, &mut rng));
    });

    let mode = MhMode::approx(0.05, 500);
    let exact = MhMode::Exact;
    let kernel = austerity::samplers::GaussianRandomWalk::new(0.01, 10.0);
    {
        let mut scratch = MhScratch::new(n);
        let mut cur = theta.clone();
        rec.bench("mh_step_approx", 200, || {
            let prop = kernel.propose(&cur, &mut rng);
            std::hint::black_box(mh_step(&model, &mut cur, prop, &mode, &mut scratch, &mut rng));
        });
        rec.bench("mh_step_exact", 20, || {
            let prop = kernel.propose(&cur, &mut rng);
            std::hint::black_box(mh_step(&model, &mut cur, prop, &exact, &mut scratch, &mut rng));
        });
    }
    {
        let mut scratch = MhScratch::new(n);
        let mut cur = theta.clone();
        let mut cache = model.init_cache(&cur);
        rec.bench("mh_step_approx_cached", 200, || {
            let prop = kernel.propose(&cur, &mut rng);
            std::hint::black_box(mh_step_cached(
                &model, &mut cur, &mut cache, prop, &mode, &mut scratch, &mut rng,
            ));
        });
        rec.bench("mh_step_exact_cached", 20, || {
            let prop = kernel.propose(&cur, &mut rng);
            std::hint::black_box(mh_step_cached(
                &model, &mut cur, &mut cache, prop, &exact, &mut scratch, &mut rng,
            ));
        });
    }

    println!("\n-- L3 acceptance rules (cached step, m = 500) --");
    for (key, iters, rule_mode) in [
        ("mh_step_cached_rule_austerity", 200usize, MhMode::approx(0.05, 500)),
        ("mh_step_cached_rule_barker", 200, MhMode::barker(1.0, 500)),
        ("mh_step_cached_rule_confidence", 200, MhMode::confidence(0.05, 500)),
        ("mh_step_cached_rule_exact", 20, MhMode::Exact),
    ] {
        let mut scratch = MhScratch::new(n);
        let mut cur = theta.clone();
        let mut cache = model.init_cache(&cur);
        let mut r = Pcg64::new(1, 2);
        rec.bench(key, iters, || {
            let prop = kernel.propose(&cur, &mut r);
            std::hint::black_box(mh_step_cached(
                &model, &mut cur, &mut cache, prop, &rule_mode, &mut scratch, &mut r,
            ));
        });
    }

    println!("\n-- L3 engine scaling (chains x 400 approx steps) --");
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    rec.record("cores", cores as f64);
    let mut sps_k1 = 0.0f64;
    for k in [1usize, 2, 4] {
        let launch = || {
            // Session rides the cached fast path for the logistic model
            Session::new(&model)
                .kernel(&kernel)
                .rule(mode.clone())
                .chains(k)
                .seed(99)
                .budget(Budget::Steps(400))
                .init(theta.clone())
                .run()
        };
        // warmup run keeps page faults and turbo ramp out of the timing
        let _ = launch();
        let t0 = Instant::now();
        let res = launch();
        let wall = t0.elapsed().as_secs_f64();
        let sps = res.merged.steps as f64 / wall;
        if k == 1 {
            sps_k1 = sps;
        }
        let scaling = sps / sps_k1;
        let ideal = k.min(cores) as f64;
        rec.record(&format!("engine_steps_per_sec_k{k}"), sps);
        rec.record(&format!("engine_scaling_k{k}_x"), scaling);
        println!(
            "engine k={k}: {sps:>9.1} steps/s, {scaling:.2}x vs k=1 ({})",
            if scaling >= 0.7 * ideal {
                "PASS >= 0.7x ideal"
            } else {
                "below 0.7x ideal"
            }
        );
    }

    // the supervised launch path with nothing to supervise: retry policy
    // armed, checkpoints rotating, watchdog ticking, zero faults — the
    // delta against `engine_steps_per_sec_k4` is the cost of resilience
    {
        let ckpt_dir = std::env::temp_dir().join(format!("austerity-bench-ckpt-{}", std::process::id()));
        let launch = || {
            Session::new(&model)
                .kernel(&kernel)
                .rule(mode.clone())
                .chains(4)
                .seed(99)
                .budget(Budget::Steps(400))
                .retry(RetryPolicy::retries(2))
                .checkpoint_every(100)
                .checkpoint_dir(ckpt_dir.clone())
                .stall_after(std::time::Duration::from_secs(30))
                .init(theta.clone())
                .run()
        };
        let _ = launch();
        let t0 = Instant::now();
        let res = launch();
        let sps = res.merged.steps as f64 / t0.elapsed().as_secs_f64();
        let _ = std::fs::remove_dir_all(&ckpt_dir);
        rec.record("retry_overhead_sps", sps);
        println!("supervised k=4 (retry+ckpt+watchdog, no faults): {sps:>9.1} steps/s");
    }

    // many small concurrent launches sharing the one global pool — the
    // workload per-launch pool construction used to penalise hardest
    {
        let (m, krn) = (&model, &kernel);
        let run_all = || -> usize {
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..4u64)
                    .map(|j| {
                        let theta_j = theta.clone();
                        let rule_j = mode.clone();
                        s.spawn(move || {
                            Session::new(m)
                                .kernel(krn)
                                .rule(rule_j)
                                .chains(2)
                                .threads(2)
                                .seed(100 + j)
                                .budget(Budget::Steps(200))
                                .init(theta_j)
                                .run()
                                .merged
                                .steps
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).sum()
            })
        };
        let _ = run_all();
        let t0 = Instant::now();
        let total = run_all();
        let sps = total as f64 / t0.elapsed().as_secs_f64();
        rec.record("executor_many_sessions_sps", sps);
        println!("4 concurrent sessions x 2 chains: {sps:>9.1} steps/s aggregate");
    }

    // the serve daemon end-to-end: admit, run, and serve small jobs
    // over real loopback HTTP — measures the whole submit→result path
    {
        use austerity::server::{ServeConfig, Server};
        use std::io::{Read, Write};

        let http = |addr: std::net::SocketAddr, method: &str, path: &str, body: &str| {
            let mut s = std::net::TcpStream::connect(addr).expect("connect");
            let req = format!(
                "{method} {path} HTTP/1.1\r\nHost: b\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            s.write_all(req.as_bytes()).expect("send");
            let mut raw = String::new();
            s.read_to_string(&mut raw).expect("recv");
            raw
        };
        let srv = Server::bind(ServeConfig {
            addr: "127.0.0.1:0".parse().unwrap(),
            max_jobs: 4,
            max_queue: 64,
            ..ServeConfig::default()
        })
        .expect("bind loopback");
        let addr = srv.local_addr();
        let stop = srv.shutdown_flag();
        let server = std::thread::spawn(move || srv.run());

        let spec = r#"{"model":{"kind":"conjugate","n":200,"data_seed":1},
                       "rule":{"kind":"exact"},"chains":2,"seed":1,
                       "budget":{"kind":"steps","steps":2000}}"#;
        const JOBS: usize = 8;
        let t0 = Instant::now();
        for _ in 0..JOBS {
            let resp = http(addr, "POST", "/jobs", spec);
            assert!(resp.contains("202"), "{resp}");
        }
        for id in 0..JOBS {
            loop {
                let resp = http(addr, "GET", &format!("/jobs/{id}"), "");
                if resp.contains("\"state\":\"done\"") {
                    break;
                }
                assert!(
                    !resp.contains("\"state\":\"failed\""),
                    "bench job failed: {resp}"
                );
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        let jps = JOBS as f64 / t0.elapsed().as_secs_f64();
        rec.record("server_jobs_per_sec", jps);
        println!("serve daemon, {JOBS} jobs x 2 chains x 2k steps: {jps:>9.2} jobs/s");
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        server.join().unwrap();
    }

    println!("\n-- L3 engine kernels (ported families via TransitionKernel) --");
    // corrected SGLD on the §6.4 toy: gradient batch + first-batch test
    let toy = LinRegModel::new(linreg_toy(10_000, 0), 3.0, 4950.0).unwrap();
    let sgld_kernel = SgldKernel {
        model: &toy,
        cfg: SgldConfig {
            alpha: 5e-6,
            grad_batch: 500,
            correction: Some(SeqTestConfig::new(0.5, 500)),
        },
    };
    for k in [1usize, 4] {
        let launch = || {
            KernelSession::new(&sgld_kernel)
                .label("sgld")
                .chains(k)
                .seed(23)
                .budget(Budget::Steps(400))
                .init(0.45f64)
                .run()
        };
        let _ = launch();
        let t0 = Instant::now();
        let res = launch();
        let sps = res.merged.steps as f64 / t0.elapsed().as_secs_f64();
        rec.record(&format!("engine_steps_per_sec_sgld_k{k}"), sps);
        println!("sgld  k={k}: {sps:>9.1} steps/s");
    }
    // approximate Gibbs sweeps on a dense binary MRF (supp. F)
    let mrf = MrfModel::random(60, 0.02, 1);
    let gibbs_kernel =
        GibbsSweepKernel { model: &mrf, mode: GibbsMode::Approx { eps: 0.1, batch: 500 } };
    let frac_ones = |x: &Vec<bool>| x.iter().filter(|&&b| b).count() as f64 / x.len() as f64;
    let x0: Vec<bool> = (0..60).map(|i| i % 2 == 0).collect();
    for k in [1usize, 4] {
        let launch = || {
            KernelSession::new(&gibbs_kernel)
                .label("gibbs")
                .chains(k)
                .seed(24)
                .budget(Budget::Steps(40))
                .record(ScalarFn::new(frac_ones))
                .init(x0.clone())
                .run()
        };
        let _ = launch();
        let t0 = Instant::now();
        let res = launch();
        let sps = res.merged.steps as f64 / t0.elapsed().as_secs_f64();
        rec.record(&format!("engine_steps_per_sec_gibbs_k{k}"), sps);
        println!("gibbs k={k}: {sps:>9.1} sweeps/s");
    }

    println!("\n-- L3 substrate --");
    rec.bench("t_sf_nu499", 10_000, || {
        std::hint::black_box(t_sf(1.7, 499.0));
    });
    rec.bench("scheduler_next_batch_500", 2_000, || {
        sched.reset();
        std::hint::black_box(sched.next_batch(500, &mut rng));
    });
    rec.bench("dp_analyze_pocock_m500", 5, || {
        std::hint::black_box(analyze_pocock(0.5, 500, n, 0.05, 256));
    });

    if PjrtRuntime::available() && PjrtRuntime::default_dir().join("manifest.txt").exists() {
        println!("\n-- L1/L2 via PJRT (AOT Pallas kernel, batch 512) --");
        let rt = PjrtRuntime::new(&PjrtRuntime::default_dir()).expect("runtime");
        let pjrt = PjrtLogistic::new(&model, rt).expect("backend");
        let t_pjrt = rec.bench("pjrt_lldiff_moments", 50, || {
            std::hint::black_box(pjrt.lldiff_moments(&idx, &theta, &theta_p));
        });
        println!(
            "{:<44} {:>9.2}x native",
            "  -> dispatch overhead ratio",
            t_pjrt / t_fused
        );
    } else {
        println!("\n(run `make artifacts` to bench the PJRT path)");
    }

    println!("\n-- speedup summary --");
    for (k, v) in &rec.rows {
        if k.starts_with("speedup_")
            || k.starts_with("full_scan_par")
            || k.starts_with("engine_scaling")
            || k.starts_with("executor_")
            || k.starts_with("shard_")
            || k.starts_with("retry_")
            || k.starts_with("server_")
        {
            println!("{k:<44} {v:>9.3}");
        }
    }

    rec.write_json("BENCH_hotpath.json");
}

//! Hot-path micro-benchmarks (`cargo bench --bench hotpath`): the
//! components on the per-MH-step critical path, timed with a simple
//! median-of-runs harness (criterion is not in the offline crate set).
//!
//! Layers:
//!   L3 native moments   — fused lldiff moment pass (the default backend)
//!   L3 sequential test  — one full approximate MH decision
//!   L3 t-CDF / scheduler / DP — supporting substrate
//!   L1/L2 via PJRT      — the AOT Pallas kernel executed through PJRT

use std::time::Instant;

use austerity::coordinator::austerity::{seq_mh_test, SeqTestConfig};
use austerity::coordinator::dp::analyze_pocock;
use austerity::coordinator::scheduler::MinibatchScheduler;
use austerity::models::traits::LlDiffModel;
use austerity::runtime::{PjrtLogistic, PjrtRuntime};
use austerity::stats::student_t::t_sf;
use austerity::stats::Pcg64;

/// Median wall time of `iters` calls, repeated 7 times.
fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters.div_ceil(4).max(1) {
        f();
    }
    let mut times: Vec<f64> = (0..7)
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            t0.elapsed().as_secs_f64() / iters as f64
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = times[3];
    let (val, unit) = if med < 1e-6 {
        (med * 1e9, "ns")
    } else if med < 1e-3 {
        (med * 1e6, "us")
    } else {
        (med * 1e3, "ms")
    };
    println!("{name:<44} {val:>9.2} {unit}/iter");
    med
}

fn main() {
    let n = 12_214usize;
    let model = austerity::exp::population::mnist_like_model(n, 42);
    let mut rng = Pcg64::seeded(0);
    let theta = model.map_estimate(60);
    let theta_p: Vec<f64> = theta.iter().map(|t| t + 0.01 * rng.normal()).collect();
    let idx: Vec<usize> = (0..500).map(|_| rng.below(n)).collect();

    println!("\n-- L3 native hot path (N = {n}, D = 50, m = 500) --");
    let t_mom = bench("lldiff_moments (500 x 50 fused)", 200, || {
        std::hint::black_box(model.lldiff_moments(&idx, &theta, &theta_p));
    });
    println!(
        "{:<44} {:>9.2} Melem/s",
        "  -> throughput",
        500.0 * 50.0 / t_mom / 1e6
    );

    let cfg = SeqTestConfig::new(0.05, 500);
    let mut sched = MinibatchScheduler::new(n);
    let mut buf = Vec::new();
    bench("seq_mh_test (full decision, eps=0.05)", 100, || {
        let mu0 = (rng.uniform_pos().ln()) / n as f64;
        std::hint::black_box(seq_mh_test(
            &model, &theta, &theta_p, mu0, &cfg, &mut sched, &mut rng, &mut buf,
        ));
    });

    println!("\n-- L3 substrate --");
    bench("student-t sf (nu = 499)", 10_000, || {
        std::hint::black_box(t_sf(1.7, 499.0));
    });
    bench("scheduler next_batch(500)", 2_000, || {
        sched.reset();
        std::hint::black_box(sched.next_batch(500, &mut rng));
    });
    bench("random-walk DP (m=500, L=256)", 5, || {
        std::hint::black_box(analyze_pocock(0.5, 500, n, 0.05, 256));
    });

    if PjrtRuntime::default_dir().join("manifest.txt").exists() {
        println!("\n-- L1/L2 via PJRT (AOT Pallas kernel, batch 512) --");
        let rt = PjrtRuntime::new(&PjrtRuntime::default_dir()).expect("runtime");
        let pjrt = PjrtLogistic::new(&model, rt).expect("backend");
        let t_pjrt = bench("pjrt lldiff_moments (512-cap kernel)", 50, || {
            std::hint::black_box(pjrt.lldiff_moments(&idx, &theta, &theta_p));
        });
        println!(
            "{:<44} {:>9.2}x native",
            "  -> dispatch overhead ratio",
            t_pjrt / t_mom
        );
    } else {
        println!("\n(run `make artifacts` to bench the PJRT path)");
    }

    println!("\n-- end-to-end step rate --");
    let mode = austerity::coordinator::MhMode::approx(0.05, 500);
    let mut scratch = austerity::coordinator::MhScratch::new(n);
    let kernel = austerity::samplers::GaussianRandomWalk::new(0.01, 10.0);
    let mut cur = theta.clone();
    bench("mh_step approx (propose + decide)", 200, || {
        use austerity::models::traits::ProposalKernel;
        let prop = kernel.propose(&cur, &mut rng);
        std::hint::black_box(austerity::coordinator::mh_step(
            &model,
            &mut cur,
            prop,
            &mode,
            &mut scratch,
            &mut rng,
        ));
    });
    let exact = austerity::coordinator::MhMode::Exact;
    bench("mh_step exact (full scan)", 20, || {
        use austerity::models::traits::ProposalKernel;
        let prop = kernel.propose(&cur, &mut rng);
        std::hint::black_box(austerity::coordinator::mh_step(
            &model,
            &mut cur,
            prop,
            &exact,
            &mut scratch,
            &mut rng,
        ));
    });
}

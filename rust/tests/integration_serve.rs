//! Server-layer integration suite: the `austerity serve` daemon
//! end-to-end over real loopback sockets.
//!
//! Pillars, matching DESIGN.md §Server layer:
//!
//! 1. **Bit-identity under concurrency** — two jobs racing on the
//!    shared executor produce draws bit-identical to the same specs
//!    run solo through `run_job` and to a hand-built `Session::run`
//!    with the same seeds: server load never touches the chains.
//! 2. **Cooperative cancel** — `DELETE /jobs/:id` mid-run settles the
//!    job as `Cancelled` with a partial-progress snapshot, and the
//!    shared executor keeps serving later jobs unpoisoned.
//! 3. **Bounded admission** — with `--max-jobs 1`, extra jobs queue
//!    (visible via `/healthz` and job states) and are admitted FIFO.
//! 4. **Malformed input** — bad JSON, NaN, duplicate keys, trailing
//!    garbage, unknown fields and wall budgets all get a 4xx carrying
//!    the typed parser error; the daemon never panics.
//! 5. **Round-trip property** — `RunReport::to_json()` output
//!    satisfies the strict reader, reserializes to an equal tree, and
//!    pins `null` for non-finite statistics.
//! 6. **Shutdown flush + resume** — shutdown mid-run cancels
//!    cooperatively, the interrupted job's chains leave checkpoints on
//!    disk, and a follow-up job with `"resume": true` finishes the run.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use austerity::coordinator::{Budget, MhMode, Session};
use austerity::server::json_in::{self, Json};
use austerity::server::jobs::run_job;
use austerity::server::spec::parse_spec;
use austerity::server::{ServeConfig, Server};
use austerity::testkit::models::ConjugateGaussian;

static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Fresh per-test checkpoint directory under the system temp dir.
fn scratch_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "austerity_serve_{tag}_{}_{}",
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Boot a daemon on a free loopback port.
fn start(cfg: ServeConfig) -> (SocketAddr, Arc<AtomicBool>, JoinHandle<()>) {
    let srv = Server::bind(cfg).expect("bind loopback");
    let addr = srv.local_addr();
    let stop = srv.shutdown_flag();
    let handle = std::thread::spawn(move || srv.run());
    (addr, stop, handle)
}

fn serve_cfg(max_jobs: usize) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".parse().unwrap(),
        max_jobs,
        max_queue: 16,
        drain: Duration::from_secs(3),
        ..ServeConfig::default()
    }
}

/// One blocking HTTP exchange; returns (status, body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).expect("send request");
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("read response");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|t| t.parse().ok())
        .unwrap_or_else(|| panic!("no status line in {raw:?}"));
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

/// Poll `GET /jobs/:id` until the state is terminal (or panic).
fn await_terminal(addr: SocketAddr, id: usize) -> String {
    for _ in 0..3_000 {
        let (status, body) = http(addr, "GET", &format!("/jobs/{id}"), "");
        assert_eq!(status, 200, "{body}");
        if ["\"done\"", "\"failed\"", "\"cancelled\""]
            .iter()
            .any(|s| body.contains(&format!("\"state\":{s}")))
        {
            return body;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("job {id} never reached a terminal state");
}

/// Per-chain draw streams of a report, as exact bit patterns.
fn draw_bits(report: &str) -> Vec<Vec<u64>> {
    let tree = json_in::parse(report).expect("report parses under the strict reader");
    let chains = tree.get("per_chain").and_then(Json::as_arr).expect("per_chain array");
    chains
        .iter()
        .map(|c| {
            c.get("draws")
                .and_then(Json::as_arr)
                .expect("draws array")
                .iter()
                .map(|d| d.as_f64().expect("finite draw").to_bits())
                .collect()
        })
        .collect()
}

const CONJ_SPEC: &str = r#"{
    "model": {"kind": "conjugate", "n": 400, "data_seed": 7},
    "rule": {"kind": "austerity", "eps": 0.05, "batch": 50},
    "chains": 2, "seed": 7,
    "budget": {"kind": "steps", "steps": 600}
}"#;

const LOGI_SPEC: &str = r#"{
    "model": {"kind": "logistic", "n": 300, "d": 5, "data_seed": 3},
    "rule": {"kind": "exact"},
    "chains": 2, "seed": 3,
    "budget": {"kind": "steps", "steps": 150}
}"#;

// ---------------------------------------------------------------- 1 --

#[test]
fn concurrent_jobs_are_bit_identical_to_solo_runs() {
    // oracle runs first, on an unloaded process
    let conj_solo = run_job(&parse_spec(CONJ_SPEC).unwrap(), None).unwrap();
    let logi_solo = run_job(&parse_spec(LOGI_SPEC).unwrap(), None).unwrap();

    let (addr, stop, handle) = start(serve_cfg(4));
    let (s1, b1) = http(addr, "POST", "/jobs", CONJ_SPEC);
    let (s2, b2) = http(addr, "POST", "/jobs", LOGI_SPEC);
    assert_eq!((s1, s2), (202, 202), "{b1} {b2}");
    await_terminal(addr, 0);
    await_terminal(addr, 1);

    let (s, conj_served) = http(addr, "GET", "/jobs/0/result", "");
    assert_eq!(s, 200, "{conj_served}");
    let (s, logi_served) = http(addr, "GET", "/jobs/1/result", "");
    assert_eq!(s, 200, "{logi_served}");

    assert_eq!(
        draw_bits(&conj_served),
        draw_bits(&conj_solo),
        "conjugate draws must not depend on server load"
    );
    assert_eq!(
        draw_bits(&logi_served),
        draw_bits(&logi_solo),
        "logistic draws must not depend on server load"
    );

    // the conjugate job also matches a hand-built Session with the
    // same seed — the server is a thin shell over the front door
    let model = ConjugateGaussian::synthetic(400, 1.0, 1.0, 0.0, 3.0, 7);
    let kernel = model.rw_proposal(0.5);
    let report = Session::new(&model)
        .kernel(&kernel)
        .rule(MhMode::approx(0.05, 50))
        .init(0.0)
        .chains(2)
        .seed(7)
        .budget(Budget::Steps(600))
        .run();
    let hand: Vec<Vec<u64>> = report
        .values()
        .iter()
        .map(|chain| chain.iter().map(|v| v.to_bits()).collect())
        .collect();
    assert_eq!(draw_bits(&conj_served), hand, "server vs hand-built Session");

    stop.store(true, Ordering::Relaxed);
    handle.join().unwrap();
}

// ---------------------------------------------------------------- 2 --

#[test]
fn cancel_mid_run_snapshots_progress_and_keeps_the_executor_healthy() {
    let (addr, stop, handle) = start(serve_cfg(2));
    // effectively unbounded: only the cancel ends it
    let big = r#"{
        "model": {"kind": "conjugate", "n": 256, "data_seed": 1},
        "rule": {"kind": "exact"},
        "chains": 2, "seed": 1,
        "budget": {"kind": "steps", "steps": 50000000}
    }"#;
    let (s, body) = http(addr, "POST", "/jobs", big);
    assert_eq!(s, 202, "{body}");

    // wait until the chains demonstrably move
    let mut started = false;
    for _ in 0..1_000 {
        let (_, b) = http(addr, "GET", "/jobs/0", "");
        let tree = json_in::parse(&b).unwrap();
        let steps = tree
            .get("progress")
            .and_then(|p| p.get("steps"))
            .and_then(Json::as_u64)
            .unwrap_or(0);
        if steps > 100 {
            started = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(started, "job never made progress");

    let (s, body) = http(addr, "DELETE", "/jobs/0", "");
    assert_eq!(s, 200, "{body}");
    let status = await_terminal(addr, 0);
    assert!(status.contains("\"state\":\"cancelled\""), "{status}");

    // the partial-progress snapshot survives the cancel
    let tree = json_in::parse(&status).unwrap();
    let steps = tree
        .get("progress")
        .and_then(|p| p.get("steps"))
        .and_then(Json::as_u64)
        .unwrap();
    assert!(steps > 0, "cancelled job must keep its progress: {status}");
    let draws = tree
        .get("progress")
        .and_then(|p| p.get("draws"))
        .and_then(Json::as_u64)
        .unwrap();
    assert!(draws > 0, "cancelled job must keep its recorded draws: {status}");

    // a cancelled launch still yields its flushed partial report
    let (s, partial) = http(addr, "GET", "/jobs/0/result", "");
    assert_eq!(s, 200, "{partial}");
    assert!(!draw_bits(&partial).is_empty());

    // the shared executor is not poisoned: a fresh job completes and
    // matches its solo oracle bit for bit
    let solo = run_job(&parse_spec(CONJ_SPEC).unwrap(), None).unwrap();
    let (s, body) = http(addr, "POST", "/jobs", CONJ_SPEC);
    assert_eq!(s, 202, "{body}");
    await_terminal(addr, 1);
    let (s, served) = http(addr, "GET", "/jobs/1/result", "");
    assert_eq!(s, 200, "{served}");
    assert_eq!(draw_bits(&served), draw_bits(&solo));

    stop.store(true, Ordering::Relaxed);
    handle.join().unwrap();
}

// ---------------------------------------------------------------- 3 --

#[test]
fn max_jobs_one_queues_then_admits_fifo() {
    let srv = Server::bind(serve_cfg(1)).expect("bind loopback");
    let addr = srv.local_addr();
    let stop = srv.shutdown_flag();
    let registry = srv.registry();
    let handle = std::thread::spawn(move || srv.run());
    let long = r#"{
        "model": {"kind": "conjugate", "n": 256, "data_seed": 4},
        "rule": {"kind": "exact"},
        "chains": 1, "seed": 4,
        "budget": {"kind": "steps", "steps": 50000000}
    }"#;
    let quick = r#"{
        "model": {"kind": "conjugate", "n": 64, "data_seed": 5},
        "rule": {"kind": "exact"},
        "chains": 1, "seed": 5,
        "budget": {"kind": "steps", "steps": 30}
    }"#;
    let (s, _) = http(addr, "POST", "/jobs", long);
    assert_eq!(s, 202);
    // wait until job 0 occupies the single runner
    for _ in 0..1_000 {
        let (_, b) = http(addr, "GET", "/jobs/0", "");
        if b.contains("\"state\":\"running\"") {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let (s, _) = http(addr, "POST", "/jobs", quick);
    assert_eq!(s, 202);
    let (s, _) = http(addr, "POST", "/jobs", quick);
    assert_eq!(s, 202);

    // both extras sit queued while job 0 hogs the only slot
    let (_, b1) = http(addr, "GET", "/jobs/1", "");
    let (_, b2) = http(addr, "GET", "/jobs/2", "");
    assert!(b1.contains("\"state\":\"queued\""), "{b1}");
    assert!(b2.contains("\"state\":\"queued\""), "{b2}");
    let (_, health) = http(addr, "GET", "/healthz", "");
    assert!(health.contains("\"queued\":2"), "{health}");
    assert!(health.contains("\"running\":1"), "{health}");

    // release the slot; the queue drains in submission order
    let (s, _) = http(addr, "DELETE", "/jobs/0", "");
    assert_eq!(s, 200);
    await_terminal(addr, 0);
    await_terminal(addr, 1);
    await_terminal(addr, 2);

    let (_, b1) = http(addr, "GET", "/jobs/1", "");
    let (_, b2) = http(addr, "GET", "/jobs/2", "");
    assert!(b1.contains("\"state\":\"done\""), "{b1}");
    assert!(b2.contains("\"state\":\"done\""), "{b2}");

    // FIFO admission, asserted via the registry's claim stamps
    let (s0, s1, s2) = (
        registry.admitted_seq(0).unwrap(),
        registry.admitted_seq(1).unwrap(),
        registry.admitted_seq(2).unwrap(),
    );
    assert!(s0 < s1 && s1 < s2, "claims must follow submission order: {s0} {s1} {s2}");

    stop.store(true, Ordering::Relaxed);
    handle.join().unwrap();
}

// ---------------------------------------------------------------- 4 --

#[test]
fn malformed_specs_get_4xx_with_the_typed_parser_error() {
    let (addr, stop, handle) = start(serve_cfg(1));
    let cases: &[(&str, &str)] = &[
        ("{\"model\":", "invalid JSON"),
        (r#"{"model":{"kind":"conjugate"},"budget":{"kind":"steps","steps":NaN}}"#, "non-finite"),
        (r#"{"seed":1,"seed":2}"#, "duplicate"),
        (r#"{"model":{"kind":"conjugate"},"budget":{"kind":"steps","steps":1}} extra"#, "trailing"),
        (r#"{"model":{"kind":"conjugate"},"budget":{"kind":"steps","steps":1},"zebra":1}"#, "unknown field"),
        (r#"{"model":{"kind":"conjugate"},"budget":{"kind":"wall","steps":1}}"#, "not reproducible"),
        (r#"{"model":{"kind":"zebra"},"budget":{"kind":"steps","steps":1}}"#, "unknown model kind"),
    ];
    for (body, needle) in cases {
        let (status, resp) = http(addr, "POST", "/jobs", body);
        assert_eq!(status, 400, "{body} -> {resp}");
        assert!(
            resp.to_lowercase().contains(&needle.to_lowercase()),
            "{body}: wanted {needle:?} in {resp}"
        );
    }
    // nothing was admitted, nothing crashed
    let (s, health) = http(addr, "GET", "/healthz", "");
    assert_eq!(s, 200);
    assert!(health.contains("\"queued\":0") && health.contains("\"running\":0"), "{health}");
    stop.store(true, Ordering::Relaxed);
    handle.join().unwrap();
}

// ---------------------------------------------------------------- 5 --

#[test]
fn run_report_json_round_trips_under_the_strict_reader() {
    // property-style: varied seeds, rules and shapes, every report must
    // (a) parse, (b) reserialize to an equal tree, (c) keep draws exact
    for seed in [1u64, 2, 3, 11, 99] {
        let model = ConjugateGaussian::synthetic(128, 0.5, 1.0, 0.0, 2.0, seed);
        let kernel = model.rw_proposal(0.4);
        let report = Session::new(&model)
            .kernel(&kernel)
            .rule(MhMode::approx(0.05, 32))
            .init(0.0)
            .chains(2)
            .seed(seed)
            .budget(Budget::Steps(80 + seed as usize))
            .run();
        let text = report.to_json();
        let tree = json_in::parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: report must parse: {e}\n{text}"));
        let again = json_in::parse(&tree.write()).unwrap();
        assert_eq!(tree, again, "seed {seed}: write→parse must be a fixed point");
        // draws survive the round trip bit for bit
        let direct: Vec<Vec<u64>> = report
            .values()
            .iter()
            .map(|c| c.iter().map(|v| v.to_bits()).collect())
            .collect();
        assert_eq!(draw_bits(&text), direct, "seed {seed}");
    }

    // non-finite statistics are pinned to null from the read side: one
    // chain of one draw has no R-hat (NaN) — the writer must emit null
    // and the reader must surface Json::Null
    let model = ConjugateGaussian::synthetic(64, 0.5, 1.0, 0.0, 2.0, 42);
    let kernel = model.rw_proposal(0.4);
    let report = Session::new(&model)
        .kernel(&kernel)
        .rule(MhMode::Exact)
        .init(0.0)
        .chains(1)
        .seed(42)
        .budget(Budget::Steps(1))
        .run();
    let text = report.to_json();
    let tree = json_in::parse(&text).unwrap();
    let rhat = tree.get("convergence").and_then(|c| c.get("rhat")).unwrap();
    assert!(rhat.is_null(), "NaN R-hat must serialize as null: {text}");
}

// ---------------------------------------------------------------- 6 --

#[test]
fn shutdown_flushes_checkpoints_and_resume_finishes_the_job() {
    let dir = scratch_dir("shutdown_resume");
    let dir_text = dir.to_string_lossy().replace('\\', "/");
    let spec = format!(
        r#"{{
            "model": {{"kind": "conjugate", "n": 256, "data_seed": 6}},
            "rule": {{"kind": "exact"}},
            "chains": 2, "seed": 6,
            "budget": {{"kind": "steps", "steps": 50000000}},
            "checkpoint_every": 200,
            "checkpoint_dir": "{dir_text}"
        }}"#
    );
    // short drain so shutdown goes straight to the cancel-and-flush path
    let mut cfg = serve_cfg(1);
    cfg.drain = Duration::from_millis(200);
    let (addr, stop, handle) = start(cfg);
    let (s, body) = http(addr, "POST", "/jobs", &spec);
    assert_eq!(s, 202, "{body}");
    // let it run long enough to cross a checkpoint boundary
    for _ in 0..1_000 {
        let (_, b) = http(addr, "GET", "/jobs/0", "");
        let steps = json_in::parse(&b)
            .ok()
            .and_then(|t| t.get("progress").and_then(|p| p.get("steps")).and_then(Json::as_u64))
            .unwrap_or(0);
        if steps > 400 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    // graceful shutdown mid-run
    stop.store(true, Ordering::Relaxed);
    handle.join().unwrap();

    // the interrupted chains left checkpoints behind
    let mut found = 0;
    if let Ok(entries) = std::fs::read_dir(&dir) {
        for e in entries.flatten() {
            if e.file_name().to_string_lossy().contains("chain") {
                found += 1;
            }
        }
    }
    assert!(found > 0, "shutdown must flush checkpoints into {}", dir.display());

    // a finite resume job picks the run back up from those checkpoints
    let resume_spec = format!(
        r#"{{
            "model": {{"kind": "conjugate", "n": 256, "data_seed": 6}},
            "rule": {{"kind": "exact"}},
            "chains": 2, "seed": 6,
            "budget": {{"kind": "steps", "steps": 1000}},
            "checkpoint_every": 200,
            "checkpoint_dir": "{dir_text}",
            "resume": true
        }}"#
    );
    let resumed = run_job(&parse_spec(&resume_spec).unwrap(), None)
        .expect("resume from the flushed checkpoints must succeed");
    let bits = draw_bits(&resumed);
    assert_eq!(bits.len(), 2);
    // each chain either extends to the 1000-step resume budget or had
    // already passed it when the shutdown flush caught it — both prove
    // the run continued from the flushed state rather than restarting
    assert!(
        bits.iter().all(|c| c.len() >= 1000),
        "resumed chains must reach the resume budget: {:?}",
        bits.iter().map(Vec::len).collect::<Vec<_>>()
    );

    std::fs::remove_dir_all(&dir).ok();
}

//! Shard-boundary bit-identity suite for the sharded columnar store and
//! the embarrassingly-parallel `Session` launch path:
//!
//! * full-scan moments over a store split into 1/2/8 segments are
//!   bit-identical to the monolithic store at 1/2/8 scan workers, for
//!   the uncached and cached paths of both SoA models, on a population
//!   deliberately not a multiple of `FULL_SCAN_CHUNK`;
//! * the same matrix holds with the spans pinned to explicit executor
//!   pools of 1/2/8 background workers;
//! * gathered minibatch kernels and segment-straddling range kernels
//!   route through the sharded store without changing a bit;
//! * a `Session::shards(1)` launch replays the plain `run()` bit for
//!   bit end to end (prior tempering by 1/1 and the one-segment store
//!   are both exact no-ops);
//! * a multi-shard launch is deterministic (same seed ⇒ same bits),
//!   tiles the population exactly, decorrelates the per-shard seeds,
//!   and produces a finite consensus combination;
//! * a shard downed whole by `GuardPolicy::Abort` is excluded from the
//!   consensus without poisoning the surviving shards, and the
//!   `ShardReport` JSON stamps the failure and degradation counts.

use austerity::coordinator::{Budget, Executor, MhMode, Param, Sample, Session};
use austerity::data::synthetic::{linreg_toy, two_class_gaussian};
use austerity::models::traits::{
    full_scan_moments_par, CachedLlDiff, LlDiffModel, ScanScratch, FULL_SCAN_CHUNK,
};
use austerity::models::{LinRegModel, LogisticModel};
use austerity::samplers::GaussianRandomWalk;
use austerity::stats::Pcg64;

/// Population size deliberately not a multiple of the scan chunk (or
/// the lane width), so the tail chunk and the last segment are ragged.
const N: usize = 5 * FULL_SCAN_CHUNK + 123;

fn logistic_sharded(n: usize, shards: usize) -> LogisticModel {
    LogisticModel::with_shards(two_class_gaussian(n, 12, 1.2, 3), 10.0, shards).unwrap()
}

fn linreg_sharded(n: usize, shards: usize) -> LinRegModel {
    LinRegModel::with_shards(linreg_toy(n, 0), 3.0, 4950.0, shards).unwrap()
}

fn params(d: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::seeded(seed);
    let cur: Vec<f64> = (0..d).map(|_| 0.2 * rng.normal()).collect();
    let prop: Vec<f64> = cur.iter().map(|t| t + 0.05 * rng.normal()).collect();
    (cur, prop)
}

#[test]
fn sharded_scan_bit_identical_across_shard_and_thread_counts() {
    let (cur, prop) = params(12, 1);
    let serial = logistic_sharded(N, 1).full_moments(&cur, &prop);
    for shards in [1usize, 2, 8] {
        let model = logistic_sharded(N, shards);
        for threads in [1usize, 2, 8] {
            let mut scan = ScanScratch::new(threads, N);
            let par = full_scan_moments_par(N, &mut scan, |a, b| {
                model.lldiff_range_moments(a, b, &cur, &prop)
            });
            assert_eq!(par.0.to_bits(), serial.0.to_bits(), "shards {shards} threads {threads}");
            assert_eq!(par.1.to_bits(), serial.1.to_bits(), "shards {shards} threads {threads}");

            let mut cache = model.init_cache(&cur);
            model.begin_step(&mut cache);
            let cached = model.cached_full_scan(&mut cache, &prop, &mut scan);
            assert_eq!(
                cached.0.to_bits(),
                serial.0.to_bits(),
                "cached shards {shards} threads {threads}"
            );
            assert_eq!(
                cached.1.to_bits(),
                serial.1.to_bits(),
                "cached shards {shards} threads {threads}"
            );
        }
    }
}

#[test]
fn sharded_scan_bit_identical_across_pool_sizes() {
    // span width (4) differs from every pool size, so spans multiplex
    // on the small pools and leave idle capacity on the large one; the
    // segment layout must not interact with either.
    let (cur, prop) = params(12, 2);
    let serial = logistic_sharded(N, 1).full_moments(&cur, &prop);
    for shards in [1usize, 2, 8] {
        let model = logistic_sharded(N, shards);
        for pool_workers in [1usize, 2, 8] {
            let pool = Executor::new(pool_workers);
            let mut scan = ScanScratch::on_pool(&pool, 4, N);
            let par = full_scan_moments_par(N, &mut scan, |a, b| {
                model.lldiff_range_moments(a, b, &cur, &prop)
            });
            assert_eq!(par.0.to_bits(), serial.0.to_bits(), "shards {shards} pool {pool_workers}");
            assert_eq!(par.1.to_bits(), serial.1.to_bits(), "shards {shards} pool {pool_workers}");

            let mut cache = model.init_cache(&cur);
            model.begin_step(&mut cache);
            let cached = model.cached_full_scan(&mut cache, &prop, &mut scan);
            assert_eq!(
                cached.0.to_bits(),
                serial.0.to_bits(),
                "cached shards {shards} pool {pool_workers}"
            );
        }
    }
}

#[test]
fn sharded_scan_bit_identical_linreg() {
    let n = 4 * FULL_SCAN_CHUNK + 77;
    let serial = linreg_sharded(n, 1).full_moments(&0.44, &0.46);
    for shards in [2usize, 3, 8] {
        let model = linreg_sharded(n, shards);
        for threads in [1usize, 2, 8] {
            let mut scan = ScanScratch::new(threads, n);
            let par = full_scan_moments_par(n, &mut scan, |a, b| {
                model.lldiff_range_moments(a, b, &0.44, &0.46)
            });
            assert_eq!(par.0.to_bits(), serial.0.to_bits(), "shards {shards} threads {threads}");
            assert_eq!(par.1.to_bits(), serial.1.to_bits(), "shards {shards} threads {threads}");

            let mut cache = model.init_cache(&0.44);
            model.begin_step(&mut cache);
            let cached = model.cached_full_scan(&mut cache, &0.46, &mut scan);
            assert_eq!(
                cached.0.to_bits(),
                serial.0.to_bits(),
                "cached shards {shards} threads {threads}"
            );
        }
    }
}

#[test]
fn gathered_and_straddling_kernels_route_through_segments_unchanged() {
    let (cur, prop) = params(12, 5);
    let mono = logistic_sharded(N, 1);
    let sharded = logistic_sharded(N, 8);
    let mut rng = Pcg64::seeded(9);

    // random gathered minibatches (the sequential-test hot path)
    for trial in 0..12 {
        let k = rng.below(700) + 1;
        let idx: Vec<u32> = (0..k).map(|_| rng.below(N) as u32).collect();
        let a = mono.lldiff_moments(&idx, &cur, &prop);
        let b = sharded.lldiff_moments(&idx, &cur, &prop);
        assert_eq!(a.0.to_bits(), b.0.to_bits(), "gathered trial {trial}");
        assert_eq!(a.1.to_bits(), b.1.to_bits(), "gathered trial {trial}");
    }

    // ranges chosen to straddle segment boundaries (8 segments over N
    // rows ⇒ boundaries at multiples of FULL_SCAN_CHUNK): the routed
    // per-row fallback must reproduce the in-segment block bits.
    for boundary in 1..5usize {
        let mid = boundary * FULL_SCAN_CHUNK;
        let (a, b) = (mid - 37, (mid + 41).min(N));
        let r_mono = mono.lldiff_range_moments(a, b, &cur, &prop);
        let r_shard = sharded.lldiff_range_moments(a, b, &cur, &prop);
        assert_eq!(r_mono.0.to_bits(), r_shard.0.to_bits(), "range [{a}, {b})");
        assert_eq!(r_mono.1.to_bits(), r_shard.1.to_bits(), "range [{a}, {b})");
    }
}

fn bits(samples: &[Sample]) -> Vec<u64> {
    samples.iter().map(|s| s.value.to_bits()).collect()
}

#[test]
fn one_shard_session_replays_the_plain_launch_bitwise() {
    let model = logistic_sharded(1_500, 1);
    let init = model.map_estimate(30);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    let build = || {
        Session::new(&model)
            .kernel(&kernel)
            .rule(MhMode::Exact)
            .chains(2)
            .seed(77)
            .budget(Budget::Steps(40))
            .record(Param::index(0))
            .init(init.clone())
    };
    let plain = build().run();
    let sharded = build().shards(1).run_sharded().unwrap();
    assert_eq!(sharded.shards.len(), 1);
    let shard = &sharded.shards[0];
    assert_eq!(shard.merged.steps, plain.merged.steps);
    assert_eq!(shard.merged.accepted, plain.merged.accepted);
    assert_eq!(shard.merged.data_used, plain.merged.data_used);
    for (a, b) in shard.runs.iter().zip(&plain.runs) {
        assert_eq!(bits(&a.samples), bits(&b.samples), "chain {}", a.chain);
    }
}

#[test]
fn multi_shard_session_is_deterministic_and_tiles_the_population() {
    let n = 1_847usize; // not divisible by 3
    let model = logistic_sharded(n, 1);
    let init = model.map_estimate(30);
    let kernel = GaussianRandomWalk::new(0.05, 10.0);
    let launch = || {
        Session::new(&model)
            .kernel(&kernel)
            .rule(MhMode::approx(0.05, 200))
            .chains(2)
            .seed(11)
            .budget(Budget::Steps(120))
            .burn_in(20)
            .record(Param::index(0))
            .init(init.clone())
            .shards(3)
            .run_sharded()
            .unwrap()
    };
    let a = launch();
    let b = launch();
    assert_eq!(a.shards.len(), 3);
    assert_eq!(a.failed_chains(), 0);

    // same seed ⇒ same bits, shard by shard, chain by chain
    for (ra, rb) in a.shards.iter().zip(&b.shards) {
        assert_eq!(ra.shard, rb.shard);
        for (ca, cb) in ra.runs.iter().zip(&rb.runs) {
            assert_eq!(bits(&ca.samples), bits(&cb.samples), "chain {}", ca.chain);
        }
    }

    // the shard stamps tile [0, n) exactly
    let mut next = 0usize;
    for (s, r) in a.shards.iter().enumerate() {
        let info = r.shard.expect("sharded runs carry their ShardInfo");
        assert_eq!(info.index, s);
        assert_eq!(info.count, 3);
        assert_eq!(info.start, next);
        next = info.end;
    }
    assert_eq!(next, n);

    // per-shard seeds decorrelate: the first recorded draws differ
    let firsts: Vec<u64> =
        a.shards.iter().map(|r| r.runs[0].samples[0].value.to_bits()).collect();
    assert!(
        firsts[0] != firsts[1] || firsts[1] != firsts[2],
        "shard chains should not replay each other"
    );

    // consensus combination exists and is finite
    let combined = a.combined().expect("combine three healthy shards");
    assert!(combined.mean.is_finite() && combined.var > 0.0);
    let total_draws: u64 = a
        .shards
        .iter()
        .flat_map(|r| r.runs.iter())
        .map(|c| c.samples.len() as u64)
        .sum();
    assert_eq!(combined.n, total_draws);
}

#[test]
fn guard_abort_downing_one_shard_leaves_the_consensus_finite() {
    use austerity::coordinator::GuardPolicy;
    use austerity::testkit::fault::{FaultKind, FaultyModel};
    use austerity::testkit::models::ConjugateGaussian;

    let inner = ConjugateGaussian::synthetic(1_200, 0.3, 1.0, 0.0, 2.0, 7);
    let proposal = inner.rw_proposal(0.4);
    // poison every chain of shard 1 at its very first step: under the
    // Abort guard both chains die before recording a draw, so the whole
    // shard degrades — the consensus must carry on over shards 0 and 2
    let model = FaultyModel::new(inner)
        .fault_on(1, 0, 0, FaultKind::Nan)
        .fault_on(1, 1, 0, FaultKind::Nan);
    let report = Session::new(&model)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(2)
        .seed(19)
        .budget(Budget::Steps(60))
        .guard(GuardPolicy::Abort)
        .init(0.0)
        .shards(3)
        .run_sharded()
        .unwrap();
    assert_eq!(report.shards.len(), 3);
    assert_eq!(report.failed_chains(), 2, "both chains of shard 1");
    assert_eq!(report.degraded_shards(), 1);
    for (s, r) in report.shards.iter().enumerate() {
        let expected_failures = if s == 1 { 2 } else { 0 };
        assert_eq!(r.failed_chains(), expected_failures, "shard {s}");
    }
    let g = report.combined().expect("the two healthy shards still combine");
    assert!(g.mean.is_finite() && g.var.is_finite() && g.var > 0.0, "consensus {g:?}");
    assert!(g.n >= 2);
    let json = report.to_json();
    assert!(json.contains("\"failed_chains\":2"), "{json}");
    assert!(json.contains("\"degraded_shards\":1"), "{json}");
    assert!(json.contains("\"consensus\":{"), "{json}");
    assert!(json.contains("\"status\":\"failed\""), "{json}");
    assert!(json.contains("numerical guard"), "{json}");
}

//! Cross-layer integration: the PJRT-executed Pallas artifacts must agree
//! with the native Rust model, and the whole approximate-MH stack must
//! run end-to-end on the PJRT backend.
//!
//! Requires `make artifacts` (tests skip with a note if absent).

use austerity::coordinator::{mh_step, MhMode, MhScratch};
use austerity::data::synthetic::two_class_gaussian;
use austerity::models::traits::{LlDiffModel, Proposal};
use austerity::models::LogisticModel;
use austerity::runtime::{PjrtLogistic, PjrtPredictor, PjrtRuntime};
use austerity::samplers::GaussianRandomWalk;
use austerity::models::traits::ProposalKernel;
use austerity::stats::Pcg64;

fn artifacts_ready() -> bool {
    // availability first: a default (stub) build must skip these tests
    // even when artifacts were built on disk
    PjrtRuntime::available() && PjrtRuntime::default_dir().join("manifest.txt").exists()
}

fn model() -> LogisticModel {
    LogisticModel::new(two_class_gaussian(12_214, 50, 1.2, 7), 10.0).unwrap()
}

#[test]
fn pjrt_moments_match_native() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let native = model();
    let rt = PjrtRuntime::new(&PjrtRuntime::default_dir()).unwrap();
    let pjrt = PjrtLogistic::new(&native, rt).unwrap();
    let mut rng = Pcg64::seeded(0);

    for trial in 0..10 {
        let theta: Vec<f64> = (0..50).map(|_| 0.1 * rng.normal()).collect();
        let theta_p: Vec<f64> =
            theta.iter().map(|t| t + 0.01 * rng.normal()).collect();
        let k = rng.below(1500) + 1;
        let idx: Vec<u32> = (0..k).map(|_| rng.below(12_214) as u32).collect();

        let (ns, ns2) = native.lldiff_moments(&idx, &theta, &theta_p);
        let (ps, ps2) = pjrt.lldiff_moments(&idx, &theta, &theta_p);
        // f32 kernel vs f64 native: tolerances scale with batch size
        let tol = 1e-4 * (k as f64).sqrt().max(1.0);
        assert!((ns - ps).abs() < tol, "trial {trial}: sum {ns} vs {ps}");
        assert!((ns2 - ps2).abs() < tol, "trial {trial}: sumsq {ns2} vs {ps2}");
    }
}

#[test]
fn pjrt_predictor_matches_native_sigmoid() {
    if !artifacts_ready() {
        return;
    }
    let native = model();
    let rt = PjrtRuntime::new(&PjrtRuntime::default_dir()).unwrap();
    let pred = PjrtPredictor::new(rt).unwrap();
    let mut rng = Pcg64::seeded(1);
    let theta: Vec<f64> = (0..50).map(|_| 0.2 * rng.normal()).collect();
    let rows: Vec<&[f64]> = (0..3000).map(|i| native.data().row(i)).collect();
    let got = pred.predict(&rows, &theta).unwrap();
    assert_eq!(got.len(), 3000);
    for (i, row) in rows.iter().enumerate() {
        let want = native.predict(row, &theta);
        assert!((got[i] - want).abs() < 1e-5, "row {i}: {} vs {want}", got[i]);
    }
}

#[test]
fn approximate_chain_runs_on_pjrt_backend() {
    if !artifacts_ready() {
        return;
    }
    // A short approximate-MH chain where every accept/reject decision is
    // served by the AOT-compiled Pallas kernel through PJRT — the full
    // three-layer architecture on the hot path.
    let native = model();
    let rt = PjrtRuntime::new(&PjrtRuntime::default_dir()).unwrap();
    let pjrt = PjrtLogistic::new(&native, rt).unwrap();

    let kernel = GaussianRandomWalk::new(0.01, 10.0);
    let mode = MhMode::approx(0.05, 500);
    let mut scratch = MhScratch::new(pjrt.n());
    let mut rng = Pcg64::seeded(2);
    let mut cur = native.map_estimate(40);

    let mut accepted = 0usize;
    let mut data_used = 0u64;
    let steps = 30;
    for _ in 0..steps {
        let prop = kernel.propose(&cur, &mut rng);
        let info = mh_step(&pjrt, &mut cur, prop, &mode, &mut scratch, &mut rng);
        accepted += info.accepted as usize;
        data_used += info.n_used as u64;
    }
    // the headline behaviour: decisions from a fraction of the data
    let frac = data_used as f64 / (steps as f64 * pjrt.n() as f64);
    assert!(frac < 1.0, "mean data fraction {frac}");
    assert!(accepted > 0, "chain frozen");
}

#[test]
fn pjrt_and_native_decisions_agree_with_shared_randomness() {
    if !artifacts_ready() {
        return;
    }
    // With identical RNG streams, the f32 kernel and the f64 native
    // model should almost always make the same accept/reject decision.
    let native = model();
    let rt = PjrtRuntime::new(&PjrtRuntime::default_dir()).unwrap();
    let pjrt = PjrtLogistic::new(&native, rt).unwrap();
    let map = native.map_estimate(40);
    let kernel = GaussianRandomWalk::new(0.01, 10.0);
    let mode = MhMode::approx(0.05, 500);

    let mut agree = 0usize;
    let trials = 25usize;
    for t in 0..trials {
        let seed = 100 + t as u64;
        let mut rng_a = Pcg64::new(seed, 5);
        let mut rng_b = Pcg64::new(seed, 5);
        let mut cur_a = map.clone();
        let mut cur_b = map.clone();
        let prop = kernel.propose(&cur_a, &mut rng_a);
        let _ = kernel.propose(&cur_b, &mut rng_b); // keep streams aligned
        let prop_b = Proposal { param: prop.param.clone(), log_correction: prop.log_correction };
        let mut scratch_a = MhScratch::new(native.n());
        let mut scratch_b = MhScratch::new(native.n());
        let a = mh_step(&native, &mut cur_a, prop, &mode, &mut scratch_a, &mut rng_a);
        let b = mh_step(&pjrt, &mut cur_b, prop_b, &mode, &mut scratch_b, &mut rng_b);
        agree += (a.accepted == b.accepted) as usize;
    }
    assert!(agree >= trials - 2, "agreement {agree}/{trials}");
}

#[test]
fn pjrt_ica_moments_match_native() {
    if !artifacts_ready() {
        return;
    }
    use austerity::data::linalg::{random_orthonormal, random_skew};
    use austerity::data::synthetic::ica_mixture;
    use austerity::models::IcaModel;
    use austerity::runtime::PjrtIca;

    let (obs, _) = ica_mixture(5_000, 3);
    let native = IcaModel::new(obs);
    let rt = PjrtRuntime::new(&PjrtRuntime::default_dir()).unwrap();
    let pjrt = PjrtIca::new(&native, rt).unwrap();
    let mut rng = Pcg64::seeded(4);
    for trial in 0..6 {
        let w = random_orthonormal(4, &mut rng);
        let wp = w.matmul(&random_skew(4, 0.05, &mut rng).expm());
        let k = rng.below(1_200) + 1;
        let idx: Vec<u32> = (0..k).map(|_| rng.below(5_000) as u32).collect();
        let (ns, ns2) = native.lldiff_moments(&idx, &w, &wp);
        let (ps, ps2) = pjrt.lldiff_moments(&idx, &w, &wp);
        let tol = 2e-4 * (k as f64).sqrt().max(1.0);
        assert!((ns - ps).abs() < tol, "trial {trial}: {ns} vs {ps}");
        assert!((ns2 - ps2).abs() < tol, "trial {trial}: {ns2} vs {ps2}");
    }
}

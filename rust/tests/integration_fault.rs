//! Fault-tolerance integration suite.
//!
//! Three pillars, matching DESIGN.md §Fault-tolerance layer:
//!
//! 1. **Checkpoint/resume bit-identity** — a launch that checkpoints,
//!    stops at a partial budget and resumes must produce draws,
//!    acceptance counters and budget accounting bitwise identical to the
//!    same-seed uninterrupted run, for the cached and uncached MH paths
//!    under all four acceptance rules plus the SGLD and Gibbs kernel
//!    families.
//! 2. **Panic isolation** — a scripted worker panic downs exactly its
//!    own chain (`ChainStatus::Failed` with the faulting step), while
//!    the other chains complete and the merged statistics stay finite.
//! 3. **Numerical guards** — NaN/Inf moments reaching an acceptance
//!    test are counted (`Warn`), force-rejected (`RejectProposal`) or
//!    turned into a single failed chain (`Abort`).

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use austerity::coordinator::record::ScalarFn;
use austerity::coordinator::{
    Budget, ChainRun, ChainStatus, GuardPolicy, KernelSession, MhMode, Sample, Session,
};
use austerity::data::synthetic::{linreg_toy, two_class_gaussian};
use austerity::models::{LinRegModel, LlDiffModel, LogisticModel, MrfModel};
use austerity::samplers::gibbs::{GibbsMode, GibbsSweepKernel};
use austerity::samplers::sgld::{SgldConfig, SgldKernel};
use austerity::samplers::GaussianRandomWalk;
use austerity::testkit::fault::{FaultKind, FaultyModel};
use austerity::testkit::models::ConjugateGaussian;

static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Fresh per-test checkpoint directory under the system temp dir.
fn scratch_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "austerity_fault_{tag}_{}_{}",
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

fn bits(samples: &[Sample]) -> Vec<u64> {
    samples.iter().map(|s| s.value.to_bits()).collect()
}

/// Chain-by-chain equality of draws (bitwise) and every counter the
/// checkpoint carries. Wall time is excluded: it is real elapsed time
/// and legitimately differs between the two runs.
fn assert_runs_identical(a: &[ChainRun], b: &[ChainRun], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: chain count");
    for (ra, rb) in a.iter().zip(b) {
        let c = ra.chain;
        assert_eq!(ra.chain, rb.chain, "{label}");
        assert_eq!(ra.stats.steps, rb.stats.steps, "{label} chain {c}: steps");
        assert_eq!(ra.stats.accepted, rb.stats.accepted, "{label} chain {c}: accepted");
        assert_eq!(ra.stats.data_used, rb.stats.data_used, "{label} chain {c}: data_used");
        assert_eq!(ra.stats.guard_trips, rb.stats.guard_trips, "{label} chain {c}: guard_trips");
        assert_eq!(bits(&ra.samples), bits(&rb.samples), "{label} chain {c}: draws");
    }
}

fn mh_modes(batch: usize) -> Vec<MhMode> {
    vec![
        MhMode::Exact,
        MhMode::approx(0.05, batch),
        MhMode::confidence(0.05, batch),
        MhMode::barker(1.0, batch),
    ]
}

// ---------------------------------------------------------------------
// 1. checkpoint/resume bit-identity
// ---------------------------------------------------------------------

#[test]
fn resume_is_bit_identical_for_uncached_mh_rules() {
    let model = ConjugateGaussian::synthetic(900, 0.3, 1.0, 0.0, 2.0, 7);
    let proposal = model.rw_proposal(0.4);
    for (i, mode) in mh_modes(64).into_iter().enumerate() {
        let dir = scratch_dir(&format!("uncached_{i}"));
        let launch = |budget: usize| {
            Session::new(&model)
                .kernel(&proposal)
                .rule(mode.clone())
                .chains(2)
                .seed(11)
                .budget(Budget::Steps(budget))
                .burn_in(10)
                .thin(2)
                .init(0.0)
        };
        let full = launch(120).run();
        assert_eq!(full.backend, "uncached");
        // interrupted run: checkpoints land at steps 15, 30, 45, 60
        let partial = launch(60).checkpoint_every(15).checkpoint_dir(dir.clone()).run();
        assert_eq!(partial.merged.steps, 2 * 60);
        let resumed = launch(120)
            .checkpoint_every(15)
            .checkpoint_dir(dir.clone())
            .resume_from(dir.clone())
            .run();
        assert_runs_identical(&resumed.runs, &full.runs, &format!("uncached {mode:?}"));
        assert_eq!(resumed.merged.data_used, full.merged.data_used, "{mode:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn resume_is_bit_identical_for_cached_mh_rules() {
    let model = LogisticModel::new(two_class_gaussian(1_200, 5, 1.2, 0), 10.0).unwrap();
    let init = model.map_estimate(40);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    for (i, mode) in mh_modes(100).into_iter().enumerate() {
        let dir = scratch_dir(&format!("cached_{i}"));
        let launch = |budget: usize| {
            Session::new(&model)
                .kernel(&kernel)
                .rule(mode.clone())
                .chains(2)
                .seed(42)
                .budget(Budget::Steps(budget))
                .burn_in(10)
                .thin(2)
                .init(init.clone())
        };
        let full = launch(120).run();
        assert_eq!(full.backend, "cached", "logistic model rides the cached path");
        let partial = launch(60).checkpoint_every(20).checkpoint_dir(dir.clone()).run();
        assert_eq!(partial.merged.steps, 2 * 60);
        // the likelihood cache is rebuilt from the restored state on
        // resume, so the cached path must still replay bit for bit
        let resumed = launch(120)
            .checkpoint_every(20)
            .checkpoint_dir(dir.clone())
            .resume_from(dir.clone())
            .run();
        assert_runs_identical(&resumed.runs, &full.runs, &format!("cached {mode:?}"));
        assert_eq!(resumed.merged.data_used, full.merged.data_used, "{mode:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn resume_is_bit_identical_for_sgld_kernel_sessions() {
    let model = LinRegModel::new(linreg_toy(2_000, 0), 3.0, 4950.0).unwrap();
    let kernel = SgldKernel {
        model: &model,
        cfg: SgldConfig { alpha: 5e-6, grad_batch: 50, correction: None },
    };
    let dir = scratch_dir("sgld");
    let launch = |budget: usize| {
        KernelSession::new(&kernel)
            .label("sgld")
            .data_size(model.n())
            .chains(2)
            .seed(9)
            .budget(Budget::Steps(budget))
            .burn_in(30)
            .init(0.45)
    };
    let full = launch(300).run();
    let partial = launch(150).checkpoint_every(50).checkpoint_dir(dir.clone()).run();
    assert_eq!(partial.merged.steps, 2 * 150);
    let resumed = launch(300)
        .checkpoint_every(50)
        .checkpoint_dir(dir.clone())
        .resume_from(dir.clone())
        .run();
    assert_runs_identical(&resumed.runs, &full.runs, "sgld");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_is_bit_identical_for_gibbs_kernel_sessions() {
    let model = MrfModel::random(24, 0.1, 2);
    let x0: Vec<bool> = (0..24).map(|i| i % 3 == 0).collect();
    for (i, mode) in
        [GibbsMode::Exact, GibbsMode::Approx { eps: 0.05, batch: 40 }].into_iter().enumerate()
    {
        let dir = scratch_dir(&format!("gibbs_{i}"));
        let kernel = GibbsSweepKernel { model: &model, mode: mode.clone() };
        let launch = |budget: usize| {
            KernelSession::new(&kernel)
                .label("gibbs")
                .chains(2)
                .seed(6)
                .budget(Budget::Steps(budget))
                .record(ScalarFn::new(|x: &Vec<bool>| {
                    x.iter().filter(|&&b| b).count() as f64
                }))
                .init(x0.clone())
        };
        let full = launch(40).run();
        let partial = launch(20).checkpoint_every(10).checkpoint_dir(dir.clone()).run();
        assert_eq!(partial.merged.steps, 2 * 20);
        let resumed = launch(40)
            .checkpoint_every(10)
            .checkpoint_dir(dir.clone())
            .resume_from(dir.clone())
            .run();
        assert_runs_identical(&resumed.runs, &full.runs, &format!("gibbs {mode:?}"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn resume_with_missing_checkpoints_starts_fresh() {
    let model = ConjugateGaussian::synthetic(400, 0.3, 1.0, 0.0, 2.0, 7);
    let proposal = model.rw_proposal(0.4);
    let dir = scratch_dir("missing");
    let launch = || {
        Session::new(&model)
            .kernel(&proposal)
            .rule(MhMode::approx(0.05, 64))
            .chains(2)
            .seed(5)
            .budget(Budget::Steps(50))
            .init(0.0)
    };
    let plain = launch().run();
    // the directory holds no chain-<c>.g<g>.ckpt files: every chain
    // starts from scratch, identical to a launch without resume at all
    // (resume always rides a checkpointed launch, so the flags pair up)
    let resumed = launch()
        .checkpoint_every(25)
        .checkpoint_dir(dir.clone())
        .resume_from(dir.clone())
        .run();
    assert_runs_identical(&resumed.runs, &plain.runs, "fresh-start resume");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// 2. per-chain panic isolation
// ---------------------------------------------------------------------

#[test]
fn scripted_panic_downs_exactly_one_chain() {
    let inner = ConjugateGaussian::synthetic(900, 0.3, 1.0, 0.0, 2.0, 7);
    let proposal = inner.rw_proposal(0.4);
    let model = FaultyModel::new(inner).fault(2, 17, FaultKind::Panic);
    let report = Session::new(&model)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(4)
        .seed(3)
        .budget(Budget::Steps(40))
        .init(0.0)
        .run();
    assert_eq!(report.chains, 4);
    assert_eq!(report.failed_chains(), 1);
    match &report.statuses[2] {
        ChainStatus::Failed { step, reason } => {
            assert_eq!(*step, 17, "fault was scripted at step 17");
            assert!(reason.contains("injected fault"), "reason: {reason}");
        }
        s => panic!("chain 2 should have failed, got {s:?}"),
    }
    for c in [0usize, 1, 3] {
        assert_eq!(report.statuses[c], ChainStatus::Completed, "chain {c}");
    }
    // survivors keep their original chain indices and full budgets
    let surviving: Vec<usize> = report.runs.iter().map(|r| r.chain).collect();
    assert_eq!(surviving, vec![0, 1, 3]);
    assert_eq!(report.merged.steps, 3 * 40);
    assert!(report.rhat().is_finite(), "rhat {}", report.rhat());
    assert!(report.ess().is_finite());
    assert!(report.pooled_mean().is_finite());
    let json = report.to_json();
    assert!(json.contains("\"failed_chains\":1"), "{json}");
    assert!(json.contains("\"status\":\"failed\""), "{json}");
    assert!(json.contains("injected fault"), "{json}");
}

#[test]
fn scripted_panic_in_scan_span_downs_only_its_chain() {
    // Exact rule + threads > chains: every step's full scan runs as
    // spans on the shared executor pool, so the scripted panic fires
    // inside a pooled span task (possibly on a worker serving other
    // chains' spans too). The executor must route the payload back to
    // the owning chain — and only that chain.
    let inner = ConjugateGaussian::synthetic(3_000, 0.3, 1.0, 0.0, 2.0, 7);
    let proposal = inner.rw_proposal(0.4);
    let model = FaultyModel::new(inner).fault(1, 5, FaultKind::Panic);
    let report = Session::new(&model)
        .kernel(&proposal)
        .rule(MhMode::Exact)
        .chains(2)
        .threads(8) // 4 intra-step scan spans per chain
        .seed(11)
        .budget(Budget::Steps(12))
        .init(0.0)
        .run();
    assert_eq!(report.failed_chains(), 1);
    match &report.statuses[1] {
        ChainStatus::Failed { step, reason } => {
            assert_eq!(*step, 5, "fault was scripted at step 5");
            assert!(reason.contains("injected fault"), "reason: {reason}");
        }
        s => panic!("chain 1 should have failed, got {s:?}"),
    }
    assert_eq!(report.statuses[0], ChainStatus::Completed);
    // the surviving chain keeps its full budget and finite statistics
    // (rhat is deliberately NaN when failures leave fewer than 2 chains)
    assert_eq!(report.merged.steps, 12);
    assert!(report.pooled_mean().is_finite());
    assert!(report.acceptance_rate().is_finite());
}

#[test]
fn merged_stats_stay_finite_with_two_failed_chains() {
    let inner = ConjugateGaussian::synthetic(900, 0.3, 1.0, 0.0, 2.0, 7);
    let proposal = inner.rw_proposal(0.4);
    let model = FaultyModel::new(inner)
        .fault(0, 3, FaultKind::Panic)
        .fault(2, 7, FaultKind::Panic);
    let report = Session::new(&model)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(4)
        .seed(3)
        .budget(Budget::Steps(40))
        .init(0.0)
        .run();
    assert_eq!(report.failed_chains(), 2);
    assert_eq!(report.runs.len(), 2);
    assert_eq!(report.merged.steps, 2 * 40);
    assert!(report.rhat().is_finite());
    assert!(report.ess().is_finite());
    assert!(report.pooled_mean().is_finite());
    assert!(report.acceptance_rate().is_finite());
}

#[test]
fn single_surviving_chain_degrades_to_nan_rhat_without_panicking() {
    let inner = ConjugateGaussian::synthetic(900, 0.3, 1.0, 0.0, 2.0, 7);
    let proposal = inner.rw_proposal(0.4);
    let model = FaultyModel::new(inner)
        .fault(0, 2, FaultKind::Panic)
        .fault(1, 2, FaultKind::Panic)
        .fault(3, 2, FaultKind::Panic);
    let report = Session::new(&model)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(4)
        .seed(3)
        .budget(Budget::Steps(40))
        .init(0.0)
        .run();
    assert_eq!(report.failed_chains(), 3);
    assert_eq!(report.runs.len(), 1);
    assert_eq!(report.merged.steps, 40);
    // cross-chain R-hat needs two chains; a degraded launch reports NaN
    // rather than a meaningless single-chain value
    assert!(report.rhat().is_nan(), "rhat {}", report.rhat());
    assert!(report.pooled_mean().is_finite());
}

#[test]
fn all_chains_failing_still_yields_a_report() {
    let inner = ConjugateGaussian::synthetic(400, 0.3, 1.0, 0.0, 2.0, 7);
    let proposal = inner.rw_proposal(0.4);
    let mut model = FaultyModel::new(inner);
    for c in 0..3 {
        model = model.fault(c, 1, FaultKind::Panic);
    }
    let report = Session::new(&model)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(3)
        .seed(3)
        .budget(Budget::Steps(20))
        .init(0.0)
        .run();
    assert_eq!(report.failed_chains(), 3);
    assert!(report.runs.is_empty());
    assert_eq!(report.merged.steps, 0);
    assert!(report.rhat().is_nan());
    // JSON still serializes (non-finite numbers become null)
    assert!(report.to_json().contains("\"failed_chains\":3"));
}

// ---------------------------------------------------------------------
// 3. numerical-guard policies
// ---------------------------------------------------------------------

#[test]
fn guard_warn_counts_trips_and_completes() {
    let inner = ConjugateGaussian::synthetic(900, 0.3, 1.0, 0.0, 2.0, 7);
    let proposal = inner.rw_proposal(0.4);
    let model = FaultyModel::new(inner).fault(0, 5, FaultKind::Nan);
    let report = Session::new(&model)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(1)
        .seed(2)
        .budget(Budget::Steps(20))
        .init(0.0)
        .run();
    assert_eq!(report.failed_chains(), 0);
    assert!(report.merged.guard_trips >= 1, "trips {}", report.merged.guard_trips);
    assert!(report.runs[0].samples.iter().all(|s| s.value.is_finite()));
    assert!(report.to_json().contains("\"guard_trips\":"));
}

#[test]
fn guard_reject_proposal_keeps_the_chain_alive() {
    let inner = ConjugateGaussian::synthetic(900, 0.3, 1.0, 0.0, 2.0, 7);
    let proposal = inner.rw_proposal(0.4);
    let model = FaultyModel::new(inner).fault(0, 5, FaultKind::Inf);
    let report = Session::new(&model)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(1)
        .seed(2)
        .budget(Budget::Steps(20))
        .guard(GuardPolicy::RejectProposal)
        .init(0.0)
        .run();
    assert_eq!(report.failed_chains(), 0);
    assert_eq!(report.merged.steps, 20);
    assert!(report.merged.guard_trips >= 1);
    assert!(report.runs[0].samples.iter().all(|s| s.value.is_finite()));
}

#[test]
fn guard_abort_downs_the_poisoned_chain_only() {
    let inner = ConjugateGaussian::synthetic(900, 0.3, 1.0, 0.0, 2.0, 7);
    let proposal = inner.rw_proposal(0.4);
    let model = FaultyModel::new(inner).fault(1, 5, FaultKind::Nan);
    let report = Session::new(&model)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(2)
        .seed(2)
        .budget(Budget::Steps(30))
        .guard(GuardPolicy::Abort)
        .init(0.0)
        .run();
    assert_eq!(report.failed_chains(), 1);
    match &report.statuses[1] {
        ChainStatus::Failed { step, reason } => {
            assert_eq!(*step, 5);
            assert!(reason.contains("numerical guard"), "reason: {reason}");
        }
        s => panic!("chain 1 should have aborted, got {s:?}"),
    }
    assert_eq!(report.statuses[0], ChainStatus::Completed);
    assert_eq!(report.runs.len(), 1);
    assert_eq!(report.runs[0].chain, 0);
    assert_eq!(report.merged.steps, 30);
}

#[test]
fn warn_guard_is_decision_transparent_on_clean_runs() {
    // a fault-free FaultyModel run under the always-on Warn guard must
    // be bit-identical to the bare model: the guard only observes.
    let bare = ConjugateGaussian::synthetic(400, 0.3, 1.0, 0.0, 2.0, 7);
    let proposal = bare.rw_proposal(0.4);
    let launch_bare = Session::new(&bare)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(2)
        .seed(8)
        .budget(Budget::Steps(60))
        .init(0.0)
        .run();
    let wrapped = FaultyModel::new(ConjugateGaussian::synthetic(400, 0.3, 1.0, 0.0, 2.0, 7));
    let launch_wrapped = Session::new(&wrapped)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(2)
        .seed(8)
        .budget(Budget::Steps(60))
        .init(0.0)
        .run();
    assert_runs_identical(&launch_wrapped.runs, &launch_bare.runs, "transparent guard");
    assert_eq!(launch_wrapped.merged.guard_trips, 0);
}

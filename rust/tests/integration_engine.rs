//! Integration tests for the parallel multi-chain engine, the
//! `TransitionKernel` abstraction and the state-caching likelihood fast
//! path:
//!
//! * deterministic replay: same seed + streams => bit-identical samples
//!   regardless of worker-pool size — for the cached MH family AND the
//!   ported SGLD / RJMCMC families;
//! * same-seed equivalence of the ported kernels against the
//!   pre-refactor bespoke loops (`run_sgld`, `run_pseudo_marginal`,
//!   hand-rolled Gibbs sweeps), kept for one release as oracles;
//! * cached vs uncached chains make bit-identical decisions on a seeded
//!   logistic chain (the cache-invalidation contract, end to end);
//! * `Budget::Data` reproduces across pool sizes (deterministic cost
//!   budgets, unlike wall clocks);
//! * `MinibatchScheduler` keeps its exchangeability guarantees when many
//!   per-chain schedulers run concurrently.

use austerity::coordinator::engine::{
    parallel_map, run_engine, run_engine_cached, run_engine_kernel, EngineConfig,
};
use austerity::coordinator::{
    drive_chain, run_chain, run_chain_cached, Budget, MhMode, MinibatchScheduler, SeqTestConfig,
};
use austerity::data::synthetic::{linreg_toy, sparse_logistic, two_class_gaussian};
use austerity::models::rjlogistic::{RjLogisticModel, RjState};
use austerity::models::{LinRegModel, LlDiffModel, LogisticModel, MrfModel};
use austerity::samplers::gibbs::{gibbs_sweep, GibbsMode, GibbsScratch, GibbsStats};
use austerity::samplers::pseudo_marginal::{run_pseudo_marginal, PmKernel, PoissonEstimator};
use austerity::samplers::sgld::{run_sgld, SgldConfig, SgldKernel};
use austerity::samplers::{GaussianRandomWalk, RjKernel, ScalarRandomWalk};
use austerity::stats::Pcg64;

fn model() -> LogisticModel {
    LogisticModel::new(two_class_gaussian(3_000, 10, 1.2, 0), 10.0).unwrap()
}

#[test]
fn engine_replay_is_identical_across_pool_sizes() {
    let model = model();
    let init = model.map_estimate(40);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    let mode = MhMode::approx(0.05, 300);
    let run = |threads: usize| {
        let cfg = EngineConfig::new(3, 42, Budget::Steps(250))
            .burn_in(50)
            .threads(threads);
        run_engine_cached(&model, &kernel, &mode, init.clone(), &cfg, |_c| {
            |t: &Vec<f64>| t[0]
        })
    };
    let serial = run(1);
    for threads in [0usize, 2, 3] {
        let par = run(threads);
        for (a, b) in serial.runs.iter().zip(&par.runs) {
            assert_eq!(a.chain, b.chain);
            assert_eq!(a.stats.steps, b.stats.steps);
            assert_eq!(a.stats.accepted, b.stats.accepted);
            assert_eq!(a.stats.data_used, b.stats.data_used);
            let va: Vec<u64> = a.samples.iter().map(|s| s.value.to_bits()).collect();
            let vb: Vec<u64> = b.samples.iter().map(|s| s.value.to_bits()).collect();
            assert_eq!(va, vb, "threads={threads}");
        }
    }
    // different chains took different paths
    assert_ne!(
        serial.runs[0].samples.last().unwrap().value,
        serial.runs[1].samples.last().unwrap().value
    );
}

#[test]
fn cached_logistic_chain_is_bit_identical_to_uncached() {
    let model = model();
    let init = model.map_estimate(40);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    for mode in [MhMode::Exact, MhMode::approx(0.05, 300)] {
        let mut rng_a = Pcg64::new(7, 3);
        let mut rng_b = Pcg64::new(7, 3);
        let (sa, sta) = run_chain(
            &model,
            &kernel,
            &mode,
            init.clone(),
            Budget::Steps(200),
            0,
            1,
            |t: &Vec<f64>| t[0],
            &mut rng_a,
        );
        let (sb, stb) = run_chain_cached(
            &model,
            &kernel,
            &mode,
            init.clone(),
            Budget::Steps(200),
            0,
            1,
            |t: &Vec<f64>| t[0],
            &mut rng_b,
        );
        assert_eq!(sta.steps, stb.steps);
        assert_eq!(sta.accepted, stb.accepted, "mode {mode:?}");
        assert_eq!(sta.data_used, stb.data_used, "mode {mode:?}");
        let va: Vec<u64> = sa.iter().map(|s| s.value.to_bits()).collect();
        let vb: Vec<u64> = sb.iter().map(|s| s.value.to_bits()).collect();
        assert_eq!(va, vb, "mode {mode:?}");
    }
}

#[test]
fn cached_linreg_chain_is_bit_identical_to_uncached() {
    let model = LinRegModel::new(linreg_toy(5_000, 0), 3.0, 4950.0).unwrap();
    let kernel = ScalarRandomWalk { sigma: 0.004, log_prior: |t: f64| -4950.0 * t.abs() };
    let mode = MhMode::approx(0.05, 400);
    let mut rng_a = Pcg64::new(21, 8);
    let mut rng_b = Pcg64::new(21, 8);
    let (sa, sta) = run_chain(
        &model, &kernel, &mode, 0.45, Budget::Steps(500), 0, 1, |&t| t, &mut rng_a,
    );
    let (sb, stb) = run_chain_cached(
        &model, &kernel, &mode, 0.45, Budget::Steps(500), 0, 1, |&t| t, &mut rng_b,
    );
    assert_eq!(sta.accepted, stb.accepted);
    assert_eq!(sta.data_used, stb.data_used);
    let va: Vec<u64> = sa.iter().map(|s| s.value.to_bits()).collect();
    let vb: Vec<u64> = sb.iter().map(|s| s.value.to_bits()).collect();
    assert_eq!(va, vb);
}

#[test]
fn engine_diagnostics_see_one_posterior() {
    // 4 chains from the same start must agree (R-hat ~ 1) and use less
    // than the full dataset per decision under the approximate test.
    let model = model();
    let init = model.map_estimate(60);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    let cfg = EngineConfig::new(4, 11, Budget::Steps(2_000)).burn_in(400);
    let res = run_engine_cached(
        &model,
        &kernel,
        &MhMode::approx(0.05, 300),
        init,
        &cfg,
        |_c| |t: &Vec<f64>| t[0],
    );
    assert_eq!(res.runs.len(), 4);
    let rhat = res.convergence.rhat;
    assert!(rhat.is_finite() && rhat < 1.3, "rhat {rhat}");
    assert!(res.convergence.ess > 20.0, "ess {}", res.convergence.ess);
    assert!(res.merged.mean_data_fraction(model.n()) < 0.9);
    assert!(res.merged.acceptance_rate() > 0.05);
}

#[test]
fn sgld_engine_replay_is_identical_across_pool_sizes() {
    let model = LinRegModel::new(linreg_toy(3_000, 0), 3.0, 4950.0).unwrap();
    let kernel = SgldKernel {
        model: &model,
        cfg: SgldConfig {
            alpha: 5e-6,
            grad_batch: 200,
            correction: Some(SeqTestConfig::new(0.3, 200)),
        },
    };
    let run = |threads: usize| {
        let cfg = EngineConfig::new(4, 77, Budget::Steps(300))
            .burn_in(50)
            .threads(threads);
        run_engine_kernel(&kernel, 0.45f64, &cfg, |_c| |t: &f64| *t)
    };
    let serial = run(1);
    for threads in [0usize, 4] {
        let par = run(threads);
        for (a, b) in serial.runs.iter().zip(&par.runs) {
            assert_eq!(a.stats.accepted, b.stats.accepted);
            assert_eq!(a.stats.data_used, b.stats.data_used);
            let va: Vec<u64> = a.samples.iter().map(|s| s.value.to_bits()).collect();
            let vb: Vec<u64> = b.samples.iter().map(|s| s.value.to_bits()).collect();
            assert_eq!(va, vb, "threads={threads}");
        }
    }
    // chains explore independently
    assert_ne!(
        serial.runs[0].samples.last().unwrap().value.to_bits(),
        serial.runs[1].samples.last().unwrap().value.to_bits()
    );
}

#[test]
fn rjmcmc_engine_replay_is_identical_across_pool_sizes() {
    let (ds, _) = sparse_logistic(2_000, 11, 3, 0.3, 0);
    let model = RjLogisticModel::new(ds, 1e-10).unwrap();
    let kernel = RjKernel::new(&model);
    let init = RjState::with_active(11, &[0], &[-0.5]);
    let run = |threads: usize| {
        let cfg = EngineConfig::new(4, 13, Budget::Steps(400))
            .burn_in(50)
            .threads(threads);
        run_engine(&model, &kernel, &MhMode::approx(0.05, 400), init.clone(), &cfg, |_c| {
            |s: &RjState| s.k() as f64
        })
    };
    let serial = run(1);
    for threads in [0usize, 4] {
        let par = run(threads);
        for (a, b) in serial.runs.iter().zip(&par.runs) {
            assert_eq!(a.stats.accepted, b.stats.accepted);
            assert_eq!(a.stats.data_used, b.stats.data_used);
            let va: Vec<u64> = a.samples.iter().map(|s| s.value.to_bits()).collect();
            let vb: Vec<u64> = b.samples.iter().map(|s| s.value.to_bits()).collect();
            assert_eq!(va, vb, "threads={threads}");
        }
    }
}

#[test]
fn sgld_kernel_matches_bespoke_loop_same_seed() {
    // The ported SGLD kernel must replay the pre-refactor `run_sgld`
    // loop bit for bit under the same RNG stream, corrected or not.
    let model = LinRegModel::new(linreg_toy(3_000, 0), 3.0, 4950.0).unwrap();
    for correction in [None, Some(SeqTestConfig::new(0.3, 200))] {
        let cfg = SgldConfig { alpha: 5e-6, grad_batch: 200, correction };
        let (steps, burn) = (500usize, 100usize);

        let mut rng_a = Pcg64::new(5, 9);
        let (bespoke, bstats) = run_sgld(&model, &cfg, 0.45, steps, burn, &mut rng_a);

        let kernel = SgldKernel { model: &model, cfg: cfg.clone() };
        let mut rng_b = Pcg64::new(5, 9);
        let (samples, stats) =
            drive_chain(&kernel, 0.45f64, Budget::Steps(steps), burn, 1, |&t| t, &mut rng_b);

        assert_eq!(bstats.steps, stats.steps);
        assert_eq!(bstats.accepted, stats.accepted);
        assert_eq!(bstats.data_used, stats.data_used);
        let va: Vec<u64> = bespoke.iter().map(|t| t.to_bits()).collect();
        let vb: Vec<u64> = samples.iter().map(|s| s.value.to_bits()).collect();
        assert_eq!(va, vb);
    }
}

#[test]
fn pm_kernel_matches_bespoke_loop_same_seed() {
    let model = LogisticModel::new(two_class_gaussian(3_000, 8, 1.2, 0), 10.0).unwrap();
    let init = model.map_estimate(40);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    let est = PoissonEstimator { batch: 100, lambda: 3.0, center: 0.0 };
    let steps = 300usize;

    let mut rng_a = Pcg64::new(8, 2);
    let mut bespoke_path = Vec::new();
    let bstats = run_pseudo_marginal(&model, &kernel, &est, init.clone(), steps, &mut rng_a, |p| {
        bespoke_path.push(p[0].to_bits());
    });

    let pm_kernel = PmKernel::new(&model, &kernel, &est, init);
    let mut rng_b = Pcg64::new(8, 2);
    let (mut clamped, mut longest_stuck) = (0usize, 0usize);
    let (samples, stats) = drive_chain(
        &pm_kernel,
        pm_kernel.init_state(),
        Budget::Steps(steps),
        0,
        1,
        |s| {
            clamped = s.clamped;
            longest_stuck = s.longest_stuck;
            s.param[0]
        },
        &mut rng_b,
    );

    assert_eq!(bstats.steps, stats.steps);
    assert_eq!(bstats.accepted, stats.accepted);
    assert_eq!(bstats.data_used, stats.data_used);
    assert_eq!(bstats.clamped, clamped);
    assert_eq!(bstats.longest_stuck, longest_stuck);
    let ported: Vec<u64> = samples.iter().map(|s| s.value.to_bits()).collect();
    assert_eq!(bespoke_path, ported);
}

#[test]
fn gibbs_sweep_kernel_matches_bespoke_loop_same_seed() {
    use austerity::samplers::gibbs::GibbsSweepKernel;

    let model = MrfModel::random(24, 0.1, 2);
    let x0: Vec<bool> = (0..24).map(|i| i % 3 == 0).collect();
    let sweeps = 40usize;
    for mode in [GibbsMode::Exact, GibbsMode::Approx { eps: 0.05, batch: 40 }] {
        let mut rng_a = Pcg64::new(6, 4);
        let mut x = x0.clone();
        let mut scratch = GibbsScratch::new(&model);
        let mut bstats = GibbsStats::default();
        let mut bespoke = Vec::new();
        for _ in 0..sweeps {
            gibbs_sweep(&model, &mut x, &mode, &mut scratch, &mut bstats, &mut rng_a);
            bespoke.push(x.clone());
        }

        let kernel = GibbsSweepKernel { model: &model, mode: mode.clone() };
        let mut rng_b = Pcg64::new(6, 4);
        let mut ported = Vec::new();
        let (_, stats) = drive_chain(
            &kernel,
            x0.clone(),
            Budget::Steps(sweeps),
            0,
            1,
            |x: &Vec<bool>| {
                ported.push(x.clone());
                0.0
            },
            &mut rng_b,
        );
        assert_eq!(stats.data_used, bstats.pairs_used);
        assert_eq!(bespoke, ported, "mode {mode:?}");
    }
}

#[test]
fn data_budget_is_deterministic_across_pool_sizes() {
    let model = model();
    let init = model.map_estimate(40);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    let budget = Budget::Data(60 * model.n() as u64 / 10); // ~a few hundred approx steps
    let run = |threads: usize| {
        let cfg = EngineConfig::new(3, 21, budget).threads(threads);
        run_engine_cached(&model, &kernel, &MhMode::approx(0.05, 300), init.clone(), &cfg, |_c| {
            |t: &Vec<f64>| t[0]
        })
    };
    let serial = run(1);
    let par = run(0);
    for (a, b) in serial.runs.iter().zip(&par.runs) {
        assert_eq!(a.stats.steps, b.stats.steps);
        assert_eq!(a.stats.data_used, b.stats.data_used);
        // the crossing step completes: budget is a floor on data_used
        assert!(a.stats.data_used >= 60 * model.n() as u64 / 10);
        let va: Vec<u64> = a.samples.iter().map(|s| s.value.to_bits()).collect();
        let vb: Vec<u64> = b.samples.iter().map(|s| s.value.to_bits()).collect();
        assert_eq!(va, vb);
    }
}

#[test]
fn concurrent_per_chain_schedulers_stay_exchangeable() {
    // Every chain owns a scheduler; concurrency must not break the
    // uniform without-replacement guarantee of each, nor determinism.
    let n = 40usize;
    let m = 10usize;
    let steps = 20_000usize;
    let draw = |c: usize| {
        let mut rng = Pcg64::new(9, 1000 + c as u64);
        let mut sched = MinibatchScheduler::new(n).unwrap();
        let mut counts = vec![0usize; n];
        for _ in 0..steps {
            sched.reset();
            let batch = sched.next_batch(m, &mut rng);
            assert_eq!(batch.len(), m);
            let mut seen = vec![false; n];
            for &i in batch {
                assert!(!seen[i as usize], "duplicate in batch");
                seen[i as usize] = true;
                counts[i as usize] += 1;
            }
        }
        counts
    };
    let concurrent = parallel_map(4, 0, &draw);
    // exchangeability: pooled first-batch inclusion is uniform
    let mut total = vec![0usize; n];
    for counts in &concurrent {
        for (t, c) in total.iter_mut().zip(counts) {
            *t += c;
        }
    }
    let expect = 4.0 * (steps * m) as f64 / n as f64;
    for (i, &c) in total.iter().enumerate() {
        assert!(
            (c as f64 - expect).abs() < 0.05 * expect,
            "index {i}: {c} vs {expect}"
        );
    }
    // and concurrency changed nothing vs serial execution
    let serial = parallel_map(4, 1, &draw);
    assert_eq!(concurrent, serial);
}

//! Integration tests for the parallel multi-chain engine and the
//! state-caching likelihood fast path:
//!
//! * deterministic replay: same seed + streams => bit-identical samples
//!   regardless of worker-pool size;
//! * cached vs uncached chains make bit-identical decisions on a seeded
//!   logistic chain (the cache-invalidation contract, end to end);
//! * `MinibatchScheduler` keeps its exchangeability guarantees when many
//!   per-chain schedulers run concurrently.

use austerity::coordinator::engine::{parallel_map, run_engine_cached, EngineConfig};
use austerity::coordinator::{run_chain, run_chain_cached, Budget, MhMode, MinibatchScheduler};
use austerity::data::synthetic::{linreg_toy, two_class_gaussian};
use austerity::models::{LinRegModel, LlDiffModel, LogisticModel};
use austerity::samplers::{GaussianRandomWalk, ScalarRandomWalk};
use austerity::stats::Pcg64;

fn model() -> LogisticModel {
    LogisticModel::new(two_class_gaussian(3_000, 10, 1.2, 0), 10.0)
}

#[test]
fn engine_replay_is_identical_across_pool_sizes() {
    let model = model();
    let init = model.map_estimate(40);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    let mode = MhMode::approx(0.05, 300);
    let run = |threads: usize| {
        let cfg = EngineConfig::new(3, 42, Budget::Steps(250))
            .burn_in(50)
            .threads(threads);
        run_engine_cached(&model, &kernel, &mode, init.clone(), &cfg, |_c| {
            |t: &Vec<f64>| t[0]
        })
    };
    let serial = run(1);
    for threads in [0usize, 2, 3] {
        let par = run(threads);
        for (a, b) in serial.runs.iter().zip(&par.runs) {
            assert_eq!(a.chain, b.chain);
            assert_eq!(a.stats.steps, b.stats.steps);
            assert_eq!(a.stats.accepted, b.stats.accepted);
            assert_eq!(a.stats.data_used, b.stats.data_used);
            let va: Vec<u64> = a.samples.iter().map(|s| s.value.to_bits()).collect();
            let vb: Vec<u64> = b.samples.iter().map(|s| s.value.to_bits()).collect();
            assert_eq!(va, vb, "threads={threads}");
        }
    }
    // different chains took different paths
    assert_ne!(
        serial.runs[0].samples.last().unwrap().value,
        serial.runs[1].samples.last().unwrap().value
    );
}

#[test]
fn cached_logistic_chain_is_bit_identical_to_uncached() {
    let model = model();
    let init = model.map_estimate(40);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    for mode in [MhMode::Exact, MhMode::approx(0.05, 300)] {
        let mut rng_a = Pcg64::new(7, 3);
        let mut rng_b = Pcg64::new(7, 3);
        let (sa, sta) = run_chain(
            &model,
            &kernel,
            &mode,
            init.clone(),
            Budget::Steps(200),
            0,
            1,
            |t: &Vec<f64>| t[0],
            &mut rng_a,
        );
        let (sb, stb) = run_chain_cached(
            &model,
            &kernel,
            &mode,
            init.clone(),
            Budget::Steps(200),
            0,
            1,
            |t: &Vec<f64>| t[0],
            &mut rng_b,
        );
        assert_eq!(sta.steps, stb.steps);
        assert_eq!(sta.accepted, stb.accepted, "mode {mode:?}");
        assert_eq!(sta.data_used, stb.data_used, "mode {mode:?}");
        let va: Vec<u64> = sa.iter().map(|s| s.value.to_bits()).collect();
        let vb: Vec<u64> = sb.iter().map(|s| s.value.to_bits()).collect();
        assert_eq!(va, vb, "mode {mode:?}");
    }
}

#[test]
fn cached_linreg_chain_is_bit_identical_to_uncached() {
    let model = LinRegModel::new(linreg_toy(5_000, 0), 3.0, 4950.0);
    let kernel = ScalarRandomWalk { sigma: 0.004, log_prior: |t: f64| -4950.0 * t.abs() };
    let mode = MhMode::approx(0.05, 400);
    let mut rng_a = Pcg64::new(21, 8);
    let mut rng_b = Pcg64::new(21, 8);
    let (sa, sta) = run_chain(
        &model, &kernel, &mode, 0.45, Budget::Steps(500), 0, 1, |&t| t, &mut rng_a,
    );
    let (sb, stb) = run_chain_cached(
        &model, &kernel, &mode, 0.45, Budget::Steps(500), 0, 1, |&t| t, &mut rng_b,
    );
    assert_eq!(sta.accepted, stb.accepted);
    assert_eq!(sta.data_used, stb.data_used);
    let va: Vec<u64> = sa.iter().map(|s| s.value.to_bits()).collect();
    let vb: Vec<u64> = sb.iter().map(|s| s.value.to_bits()).collect();
    assert_eq!(va, vb);
}

#[test]
fn engine_diagnostics_see_one_posterior() {
    // 4 chains from the same start must agree (R-hat ~ 1) and use less
    // than the full dataset per decision under the approximate test.
    let model = model();
    let init = model.map_estimate(60);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    let cfg = EngineConfig::new(4, 11, Budget::Steps(2_000)).burn_in(400);
    let res = run_engine_cached(
        &model,
        &kernel,
        &MhMode::approx(0.05, 300),
        init,
        &cfg,
        |_c| |t: &Vec<f64>| t[0],
    );
    assert_eq!(res.runs.len(), 4);
    let rhat = res.convergence.rhat;
    assert!(rhat.is_finite() && rhat < 1.3, "rhat {rhat}");
    assert!(res.convergence.ess > 20.0, "ess {}", res.convergence.ess);
    assert!(res.merged.mean_data_fraction(model.n()) < 0.9);
    assert!(res.merged.acceptance_rate() > 0.05);
}

#[test]
fn concurrent_per_chain_schedulers_stay_exchangeable() {
    // Every chain owns a scheduler; concurrency must not break the
    // uniform without-replacement guarantee of each, nor determinism.
    let n = 40usize;
    let m = 10usize;
    let steps = 20_000usize;
    let draw = |c: usize| {
        let mut rng = Pcg64::new(9, 1000 + c as u64);
        let mut sched = MinibatchScheduler::new(n);
        let mut counts = vec![0usize; n];
        for _ in 0..steps {
            sched.reset();
            let batch = sched.next_batch(m, &mut rng);
            assert_eq!(batch.len(), m);
            let mut seen = vec![false; n];
            for &i in batch {
                assert!(!seen[i as usize], "duplicate in batch");
                seen[i as usize] = true;
                counts[i as usize] += 1;
            }
        }
        counts
    };
    let concurrent = parallel_map(4, 0, &draw);
    // exchangeability: pooled first-batch inclusion is uniform
    let mut total = vec![0usize; n];
    for counts in &concurrent {
        for (t, c) in total.iter_mut().zip(counts) {
            *t += c;
        }
    }
    let expect = 4.0 * (steps * m) as f64 / n as f64;
    for (i, &c) in total.iter().enumerate() {
        assert!(
            (c as f64 - expect).abs() < 0.05 * expect,
            "index {i}: {c} vs {expect}"
        );
    }
    // and concurrency changed nothing vs serial execution
    let serial = parallel_map(4, 1, &draw);
    assert_eq!(concurrent, serial);
}

//! End-to-end chain integration: the approximate chain must sample the
//! same posterior as the exact chain on every §6 model, while using less
//! data — the paper's core claim, checked across the whole stack.

use austerity::coordinator::{run_chain, Budget, MhMode};
use austerity::data::synthetic::{ica_mixture, linreg_toy, sparse_logistic, two_class_gaussian};
use austerity::models::ica::amari_distance;
use austerity::models::rjlogistic::{RjLogisticModel, RjState};
use austerity::models::{IcaModel, LinRegModel, LlDiffModel, LogisticModel};
use austerity::samplers::{GaussianRandomWalk, RjKernel, ScalarRandomWalk, StiefelRandomWalk};
use austerity::stats::welford::Welford;
use austerity::stats::Pcg64;

fn summarize(samples: &[austerity::coordinator::Sample]) -> Welford {
    let mut w = Welford::new();
    for s in samples {
        w.add(s.value);
    }
    w
}

#[test]
fn logistic_posterior_matches_across_modes() {
    let model = LogisticModel::new(two_class_gaussian(6_000, 8, 1.2, 0), 10.0).unwrap();
    let init = model.map_estimate(60);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    let steps = 8_000;

    let mut stats_by_eps = Vec::new();
    for eps in [0.0, 0.05] {
        let mut rng = Pcg64::seeded(3);
        let (samples, stats) = run_chain(
            &model,
            &kernel,
            &MhMode::approx(eps, 500),
            init.clone(),
            Budget::Steps(steps),
            1_000,
            1,
            |t| t[0],
            &mut rng,
        );
        stats_by_eps.push((summarize(&samples), stats));
    }
    let (exact_w, exact_stats) = &stats_by_eps[0];
    let (approx_w, approx_stats) = &stats_by_eps[1];

    // posterior means agree within combined MC error
    let tol = 4.0 * (exact_w.std_sample() + approx_w.std_sample())
        / (exact_w.n() as f64).sqrt()
        + 0.02;
    assert!(
        (exact_w.mean() - approx_w.mean()).abs() < tol,
        "means {} vs {} (tol {tol})",
        exact_w.mean(),
        approx_w.mean()
    );
    // data austerity
    assert!((exact_stats.mean_data_fraction(model.n()) - 1.0).abs() < 1e-12);
    assert!(approx_stats.mean_data_fraction(model.n()) < 0.8);
}

#[test]
fn ica_posterior_amari_matches_across_modes() {
    let (obs, w0) = ica_mixture(20_000, 5);
    let model = IcaModel::new(obs);
    let kernel = StiefelRandomWalk::new(0.05);
    let steps = 1_200;

    let mut results = Vec::new();
    for eps in [0.0, 0.05] {
        let w0c = w0.clone();
        let mut rng = Pcg64::seeded(4);
        let (samples, stats) = run_chain(
            &model,
            &kernel,
            &MhMode::approx(eps, 600),
            w0.clone(),
            Budget::Steps(steps),
            200,
            1,
            move |w| amari_distance(w, &w0c),
            &mut rng,
        );
        results.push((summarize(&samples), stats));
    }
    let exact = results[0].0.mean();
    let approx = results[1].0.mean();
    assert!(
        (exact - approx).abs() < 0.05,
        "E[amari] exact {exact} vs approx {approx}"
    );
    assert!(results[1].1.mean_data_fraction(model.n()) < 0.9);
    // posterior concentrates near the true unmixing matrix
    assert!(exact < 0.2, "exact E[amari] {exact}");
}

#[test]
fn linreg_scalar_chain_matches_quadrature() {
    // exact-MH random walk on the SGLD toy posterior vs quadrature truth
    let model = LinRegModel::new(linreg_toy(10_000, 0), 3.0, 4950.0).unwrap();
    let (grid, dens) = model.posterior_density(-0.2, 0.8, 4_000);
    let h = grid[1] - grid[0];
    let t_mean: f64 = grid.iter().zip(&dens).map(|(t, d)| t * d * h).sum();

    let kernel = ScalarRandomWalk { sigma: 0.004, log_prior: |t: f64| -4950.0 * t.abs() };
    let mut rng = Pcg64::seeded(6);
    let (samples, stats) = run_chain(
        &model,
        &kernel,
        &MhMode::approx(0.05, 500),
        t_mean,
        Budget::Steps(20_000),
        2_000,
        1,
        |&t| t,
        &mut rng,
    );
    let w = summarize(&samples);
    assert!(
        (w.mean() - t_mean).abs() < 0.01,
        "chain mean {} vs quadrature {}",
        w.mean(),
        t_mean
    );
    assert!(stats.acceptance_rate() > 0.2);
    assert!(stats.mean_data_fraction(model.n()) < 1.0);
}

#[test]
fn rjmcmc_approx_recovers_same_support_as_exact() {
    let (ds, beta_true) = sparse_logistic(15_000, 13, 3, 0.3, 2);
    let model = RjLogisticModel::new(ds, 1e-10).unwrap();
    let truly_active: Vec<usize> = (1..13).filter(|&j| beta_true[j] != 0.0).collect();
    let steps = 10_000;

    let mut per_mode = Vec::new();
    for eps in [0.0, 0.05] {
        let kernel = RjKernel::new(&model);
        let mut rng = Pcg64::seeded(8);
        let mut incl = vec![0u64; 13];
        let mut count = 0u64;
        let (_, stats) = run_chain(
            &model,
            &kernel,
            &MhMode::approx(eps, 500),
            RjState::with_active(13, &[0], &[-0.7]),
            Budget::Steps(steps),
            2_000,
            1,
            |s| {
                for &j in &s.active {
                    incl[j] += 1;
                }
                count += 1;
                0.0
            },
            &mut rng,
        );
        let probs: Vec<f64> = incl.iter().map(|&c| c as f64 / count as f64).collect();
        per_mode.push((probs, stats.mean_data_fraction(model.n())));
    }
    for (label, (probs, _)) in ["exact", "approx"].iter().zip(&per_mode) {
        let on: f64 = truly_active.iter().map(|&j| probs[j]).sum::<f64>()
            / truly_active.len() as f64;
        let off: f64 = (1..13)
            .filter(|j| !truly_active.contains(j))
            .map(|j| probs[j])
            .sum::<f64>()
            / (12 - truly_active.len()) as f64;
        assert!(on > off + 0.3, "{label}: active {on} vs inactive {off}");
    }
    assert!(per_mode[1].1 < 0.7, "approx data fraction {}", per_mode[1].1);
}

//! Integration tests for the pluggable acceptance-test layer:
//!
//! * same-seed equivalence of the ported `ExactTest` / `AusterityTest`
//!   against hand-rolled oracles replicating the pre-refactor
//!   `mh_step{,_cached}` code shape (u draw, then full scan or
//!   `seq_mh_test{,_cached}`) — the bit-identity guarantee of the port;
//! * replay determinism of the new `BarkerTest` / `ConfidenceTest`
//!   members across engine worker-pool sizes;
//! * all four rules running through `run_engine_kernel` on K = 4 chains
//!   under a deterministic `Budget::Data`;
//! * statistical validation of `ExactTest` on the conjugate Gaussian
//!   model via the `testkit::validate` harness (chi-square vs the
//!   analytic posterior + moment z-scores), with longer `#[ignore]`d
//!   variants for the slow-CI job covering the approximate rules too.
//!
//! The zero-allocation assertion on the cached hot path lives in
//! `tests/alloc_hotpath.rs` — it needs a counting global allocator and
//! therefore a binary with exactly one test.

use austerity::coordinator::austerity::{seq_mh_test, seq_mh_test_cached, SeqTestConfig};
use austerity::coordinator::engine::{run_engine_cached, EngineConfig};
use austerity::coordinator::{run_chain, Budget, MhMode, MhScratch, MinibatchScheduler};
use austerity::coordinator::{mh_step, mh_step_cached};
use austerity::data::synthetic::two_class_gaussian;
use austerity::models::traits::{
    full_scan_moments, CachedLlDiff, LlDiffModel, Proposal, ProposalKernel,
};
use austerity::models::LogisticModel;
use austerity::samplers::GaussianRandomWalk;
use austerity::stats::{Histogram, Pcg64, Welford};
use austerity::testkit::models::ConjugateGaussian;
use austerity::testkit::validate::{chi_square_hist, moment_z};

fn model() -> LogisticModel {
    LogisticModel::new(two_class_gaussian(3_000, 10, 1.2, 0), 10.0).unwrap()
}

/// The pre-refactor `mh_step` shape, byte for byte: draw u, resolve an
/// infinite correction without data, then either a chunked full scan or
/// the standalone sequential test. The exact arm streams the *gathered*
/// chunk scan — the production path is range-based, so agreement here
/// also regression-tests the `lldiff_range_moments` bit contract.
enum OracleMode {
    Exact,
    Approx(SeqTestConfig),
}

#[allow(clippy::too_many_arguments)]
fn oracle_step<M: LlDiffModel>(
    model: &M,
    cur: &mut M::Param,
    proposal: Proposal<M::Param>,
    mode: &OracleMode,
    sched: &mut MinibatchScheduler,
    idx_buf: &mut Vec<u32>,
    rng: &mut Pcg64,
) -> (bool, usize, usize) {
    let n = model.n() as f64;
    let u = rng.uniform_pos();
    if proposal.log_correction == f64::INFINITY {
        return (false, 0, 0);
    }
    let mu0 = (u.ln() + proposal.log_correction) / n;
    let cur_ref: &M::Param = cur;
    let (accepted, used, stages) = match mode {
        OracleMode::Exact => {
            let (s, _) = full_scan_moments(model.n(), idx_buf, |idx| {
                model.lldiff_moments(idx, cur_ref, &proposal.param)
            });
            (s / n > mu0, model.n(), 1)
        }
        OracleMode::Approx(cfg) => {
            let out = seq_mh_test(model, cur_ref, &proposal.param, mu0, cfg, sched, rng);
            (out.accept, out.n_used, out.stages)
        }
    };
    if accepted {
        *cur = proposal.param;
    }
    (accepted, used, stages)
}

/// The pre-refactor `mh_step_cached` shape (begin_step, cached full scan
/// or `seq_mh_test_cached`, end_step).
#[allow(clippy::too_many_arguments)]
fn oracle_step_cached<M: CachedLlDiff>(
    model: &M,
    cur: &mut M::Param,
    cache: &mut M::Cache,
    proposal: Proposal<M::Param>,
    mode: &OracleMode,
    sched: &mut MinibatchScheduler,
    idx_buf: &mut Vec<u32>,
    rng: &mut Pcg64,
) -> (bool, usize, usize) {
    let n = model.n() as f64;
    let u = rng.uniform_pos();
    if proposal.log_correction == f64::INFINITY {
        return (false, 0, 0);
    }
    let mu0 = (u.ln() + proposal.log_correction) / n;
    model.begin_step(cache);
    let (accepted, used, stages) = match mode {
        OracleMode::Exact => {
            let (s, _) = full_scan_moments(model.n(), idx_buf, |idx| {
                model.cached_moments(cache, idx, &proposal.param)
            });
            (s / n > mu0, model.n(), 1)
        }
        OracleMode::Approx(cfg) => {
            let out = seq_mh_test_cached(model, cache, &proposal.param, mu0, cfg, sched, rng);
            (out.accept, out.n_used, out.stages)
        }
    };
    model.end_step(cache, &proposal.param, accepted);
    if accepted {
        *cur = proposal.param;
    }
    (accepted, used, stages)
}

#[test]
fn ported_tests_match_prerefactor_oracle_uncached() {
    let model = model();
    let init = model.map_estimate(40);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    for (mode, oracle) in [
        (MhMode::Exact, OracleMode::Exact),
        (MhMode::approx(0.05, 300), OracleMode::Approx(SeqTestConfig::new(0.05, 300))),
    ] {
        let mut rng_a = Pcg64::new(7, 3);
        let mut rng_b = Pcg64::new(7, 3);
        let mut scratch = MhScratch::new(model.n());
        let mut sched = MinibatchScheduler::new(model.n()).unwrap();
        let mut buf: Vec<u32> = Vec::new();
        let mut cur_a = init.clone();
        let mut cur_b = init.clone();
        for step in 0..200 {
            let prop_a = kernel.propose(&cur_a, &mut rng_a);
            let prop_b = kernel.propose(&cur_b, &mut rng_b);
            let a = mh_step(&model, &mut cur_a, prop_a, &mode, &mut scratch, &mut rng_a);
            let b = oracle_step(
                &model, &mut cur_b, prop_b, &oracle, &mut sched, &mut buf, &mut rng_b,
            );
            assert_eq!((a.accepted, a.n_used, a.stages), b, "mode {mode:?} step {step}");
            let bits_a: Vec<u64> = cur_a.iter().map(|t| t.to_bits()).collect();
            let bits_b: Vec<u64> = cur_b.iter().map(|t| t.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "mode {mode:?} step {step}");
        }
        // the streams must end in the same position
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }
}

#[test]
fn ported_tests_match_prerefactor_oracle_cached() {
    let model = model();
    let init = model.map_estimate(40);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    for (mode, oracle) in [
        (MhMode::Exact, OracleMode::Exact),
        (MhMode::approx(0.05, 300), OracleMode::Approx(SeqTestConfig::new(0.05, 300))),
    ] {
        let mut rng_a = Pcg64::new(21, 8);
        let mut rng_b = Pcg64::new(21, 8);
        let mut scratch = MhScratch::new(model.n());
        let mut sched = MinibatchScheduler::new(model.n()).unwrap();
        let mut buf: Vec<u32> = Vec::new();
        let mut cur_a = init.clone();
        let mut cur_b = init.clone();
        let mut cache_a = model.init_cache(&cur_a);
        let mut cache_b = model.init_cache(&cur_b);
        for step in 0..200 {
            let prop_a = kernel.propose(&cur_a, &mut rng_a);
            let prop_b = kernel.propose(&cur_b, &mut rng_b);
            let a = mh_step_cached(
                &model, &mut cur_a, &mut cache_a, prop_a, &mode, &mut scratch, &mut rng_a,
            );
            let b = oracle_step_cached(
                &model, &mut cur_b, &mut cache_b, prop_b, &oracle, &mut sched, &mut buf,
                &mut rng_b,
            );
            assert_eq!((a.accepted, a.n_used, a.stages), b, "mode {mode:?} step {step}");
            let bits_a: Vec<u64> = cur_a.iter().map(|t| t.to_bits()).collect();
            let bits_b: Vec<u64> = cur_b.iter().map(|t| t.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "mode {mode:?} step {step}");
        }
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }
}

#[test]
fn barker_and_confidence_replay_across_pool_sizes() {
    let model = model();
    let init = model.map_estimate(40);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    for mode in [MhMode::barker(1.0, 300), MhMode::confidence(0.05, 300)] {
        let run = |threads: usize| {
            let cfg = EngineConfig::new(4, 42, Budget::Steps(200))
                .burn_in(40)
                .threads(threads);
            run_engine_cached(&model, &kernel, &mode, init.clone(), &cfg, |_c| {
                |t: &Vec<f64>| t[0]
            })
        };
        let serial = run(1);
        for threads in [0usize, 2, 3] {
            let par = run(threads);
            for (a, b) in serial.runs.iter().zip(&par.runs) {
                assert_eq!(a.stats.steps, b.stats.steps, "mode {mode:?}");
                assert_eq!(a.stats.accepted, b.stats.accepted, "mode {mode:?}");
                assert_eq!(a.stats.data_used, b.stats.data_used, "mode {mode:?}");
                let va: Vec<u64> = a.samples.iter().map(|s| s.value.to_bits()).collect();
                let vb: Vec<u64> = b.samples.iter().map(|s| s.value.to_bits()).collect();
                assert_eq!(va, vb, "mode {mode:?} threads {threads}");
            }
        }
        // chains explore independently
        assert_ne!(
            serial.runs[0].samples.last().unwrap().value.to_bits(),
            serial.runs[1].samples.last().unwrap().value.to_bits()
        );
    }
}

#[test]
fn all_four_rules_run_on_engine_k4_under_data_budget() {
    let model = model();
    let init = model.map_estimate(60);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    let budget = Budget::Data(40 * model.n() as u64);
    for mode in [
        MhMode::Exact,
        MhMode::approx(0.05, 300),
        MhMode::barker(1.0, 300),
        MhMode::confidence(0.05, 300),
    ] {
        let cfg = EngineConfig::new(4, 11, budget).burn_in(5);
        let res = run_engine_cached(&model, &kernel, &mode, init.clone(), &cfg, |_c| {
            |t: &Vec<f64>| t[0]
        });
        assert_eq!(res.runs.len(), 4, "mode {mode:?}");
        for run in &res.runs {
            assert!(run.stats.data_used >= 40 * model.n() as u64, "mode {mode:?}");
            assert!(!run.samples.is_empty(), "mode {mode:?}");
        }
        assert!(res.merged.acceptance_rate() > 0.0, "mode {mode:?}");
        assert!(res.convergence.rhat.is_finite(), "mode {mode:?}");
        // every budgeted rule must beat the exact rule's step count
        if !matches!(mode, MhMode::Exact) {
            assert!(res.merged.mean_data_fraction(model.n()) <= 1.0, "mode {mode:?}");
        }
    }
}

/// Run one rule on the conjugate Gaussian target and return the
/// histogram + moment accumulator of the post-burn-in thinned output.
fn conjugate_run(mode: &MhMode, steps: usize, thin: usize, seed: u64) -> (Histogram, Welford) {
    let target = ConjugateGaussian::synthetic(200, 1.5, 2.0, 0.0, 10.0_f64.sqrt(), 3);
    let kernel = target.rw_proposal(2.5 * target.posterior_var().sqrt());
    let mut rng = Pcg64::new(seed, 1000);
    let (samples, stats) = run_chain(
        &target,
        &kernel,
        mode,
        target.posterior_mean(),
        Budget::Steps(steps),
        steps / 10,
        thin,
        |&t| t,
        &mut rng,
    );
    assert!(stats.acceptance_rate() > 0.15 && stats.acceptance_rate() < 0.85);
    let (mn, sd) = (target.posterior_mean(), target.posterior_var().sqrt());
    let mut h = Histogram::new(mn - 4.5 * sd, mn + 4.5 * sd, 30);
    let mut w = Welford::new();
    for s in &samples {
        h.add(s.value);
        w.add(s.value);
    }
    (h, w)
}

fn conjugate_target() -> ConjugateGaussian {
    ConjugateGaussian::synthetic(200, 1.5, 2.0, 0.0, 10.0_f64.sqrt(), 3)
}

#[test]
fn exact_chain_matches_conjugate_posterior() {
    // satellite: the statistical-validation harness applied to ExactTest
    let target = conjugate_target();
    let (h, w) = conjugate_run(&MhMode::Exact, 40_000, 10, 12);
    let gof = chi_square_hist(&h, |x| target.posterior_cdf(x));
    assert!(gof.p_value > 1e-5, "posterior mismatch: {gof:?}");
    // thin-10 RW output is near-independent; be conservative about ESS
    let mz = moment_z(&w, target.posterior_mean(), target.posterior_var(), w.n() as f64 / 3.0);
    assert!(mz.mean_z.abs() < 6.0, "{mz:?}");
    assert!(mz.var_z.abs() < 6.0, "{mz:?}");
}

#[test]
#[ignore = "slow statistical validation (run via cargo test --release -- --ignored)"]
fn exact_chain_posterior_validation_long() {
    let target = conjugate_target();
    let (h, w) = conjugate_run(&MhMode::Exact, 400_000, 10, 13);
    let gof = chi_square_hist(&h, |x| target.posterior_cdf(x));
    assert!(gof.p_value > 1e-4, "posterior mismatch: {gof:?}");
    let mz = moment_z(&w, target.posterior_mean(), target.posterior_var(), w.n() as f64 / 3.0);
    assert!(mz.mean_z.abs() < 5.0, "{mz:?}");
    assert!(mz.var_z.abs() < 5.0, "{mz:?}");
}

#[test]
#[ignore = "slow statistical validation (run via cargo test --release -- --ignored)"]
fn approximate_rules_stay_near_conjugate_posterior_long() {
    // The budgeted rules carry a small, knob-controlled bias; with tight
    // knobs they must stay statistically close to the analytic
    // posterior. Thresholds are looser than the exact test's — this
    // guards against gross targeting bugs, not the knob's designed bias.
    let target = conjugate_target();
    for (label, mode) in [
        ("austerity", MhMode::approx(0.01, 100)),
        ("barker", MhMode::barker(1.0, 100)),
        ("confidence", MhMode::confidence(0.01, 100)),
    ] {
        let (h, w) = conjugate_run(&mode, 400_000, 10, 14);
        let gof = chi_square_hist(&h, |x| target.posterior_cdf(x));
        assert!(gof.p_value > 1e-8, "{label}: {gof:?}");
        let mz =
            moment_z(&w, target.posterior_mean(), target.posterior_var(), w.n() as f64 / 3.0);
        assert!(mz.mean_z.abs() < 10.0, "{label}: {mz:?}");
        assert!(mz.var_z.abs() < 10.0, "{label}: {mz:?}");
    }
}

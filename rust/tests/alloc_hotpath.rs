//! Zero-allocation regression for the cached MH hot path, for every
//! acceptance rule.
//!
//! This file must contain exactly ONE test: it installs a counting
//! global allocator, and a single-test binary is the only way to
//! guarantee no other test thread allocates during the measured window.
//! (That is why this assertion does not live in `integration_accept.rs`
//! with the rest of the acceptance-layer suite.)
//!
//! The measured region is the steady state: scratch, caches and the
//! Barker correction table are built (and capacities warmed) beforehand;
//! 300 proposal + `mh_step_cached` iterations must then perform zero
//! heap allocations. The model is the scalar-parameter `LinRegModel`, so
//! proposals themselves are allocation-free and the assertion covers the
//! full step, not just the decision.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use austerity::coordinator::{mh_step_cached, MhMode, MhScratch};
use austerity::data::synthetic::linreg_toy;
use austerity::models::traits::{CachedLlDiff, LlDiffModel, ProposalKernel};
use austerity::models::LinRegModel;
use austerity::samplers::ScalarRandomWalk;
use austerity::stats::Pcg64;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn cached_hot_path_steady_state_allocates_nothing() {
    let model = LinRegModel::new(linreg_toy(5_000, 0), 3.0, 4950.0);
    let kernel = ScalarRandomWalk { sigma: 0.004, log_prior: |t: f64| -4950.0 * t.abs() };
    let modes = [
        ("exact", MhMode::Exact),
        ("austerity", MhMode::approx(0.05, 400)),
        ("barker", MhMode::barker(1.0, 400)),
        ("confidence", MhMode::confidence(0.05, 400)),
    ];
    for (name, mode) in modes {
        let mut rng = Pcg64::new(3, 9);
        let mut scratch = MhScratch::new(model.n());
        // pre-warm capacities a long confidence/exhaustion decision could
        // otherwise grow mid-measurement
        scratch.idx_buf.reserve(model.n());
        scratch.trace.reserve(64);
        let mut cur = 0.45f64;
        let mut cache = model.init_cache(&cur);
        for _ in 0..200 {
            let p = kernel.propose(&cur, &mut rng);
            mh_step_cached(&model, &mut cur, &mut cache, p, &mode, &mut scratch, &mut rng);
        }

        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..300 {
            let p = kernel.propose(&cur, &mut rng);
            mh_step_cached(&model, &mut cur, &mut cache, p, &mode, &mut scratch, &mut rng);
        }
        let delta = ALLOCS.load(Ordering::SeqCst) - before;
        assert_eq!(delta, 0, "rule {name}: {delta} heap allocations on the cached hot path");
    }
}

//! Zero-allocation regression for the cached MH hot path (every
//! acceptance rule) and for the workers of the deterministic parallel
//! exact scan.
//!
//! This file must contain exactly ONE test: it installs a counting
//! global allocator, and a single-test binary is the only way to
//! guarantee no other test thread allocates during the measured window.
//! (That is why this assertion does not live in `integration_accept.rs`
//! with the rest of the acceptance-layer suite.)
//!
//! Phase 1 — the measured region is the steady state: scratch, caches
//! and the Barker correction table are built (and capacities warmed)
//! beforehand; 300 proposal + `mh_step_cached` iterations must then
//! perform zero heap allocations. The model is the scalar-parameter
//! `LinRegModel`, so proposals themselves are allocation-free and the
//! assertion covers the full step, not just the decision.
//!
//! Phase 2 — the parallel-scan exact path: after a warmup scan, every
//! *worker-side* chunk evaluation of `full_scan_moments_par` /
//! `cached_full_scan` must allocate nothing (asserted via a
//! thread-local allocation counter around each chunk kernel call; the
//! coordinating thread still pays the scoped-thread spawn, which is why
//! the assertion is per worker, not global).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

use austerity::coordinator::{mh_step_cached, MhMode, MhScratch};
use austerity::data::synthetic::linreg_toy;
use austerity::models::traits::{
    full_scan_moments_par, CachedLlDiff, LlDiffModel, ProposalKernel, ScanScratch,
};
use austerity::models::LinRegModel;
use austerity::samplers::ScalarRandomWalk;
use austerity::stats::Pcg64;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    // const-initialized Cell: safe to touch from inside the allocator
    // (no lazy init, no drop registration)
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn tl_allocs() -> u64 {
    TL_ALLOCS.with(|c| c.get())
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn cached_hot_path_steady_state_allocates_nothing() {
    let model = LinRegModel::new(linreg_toy(5_000, 0), 3.0, 4950.0).unwrap();
    let kernel = ScalarRandomWalk { sigma: 0.004, log_prior: |t: f64| -4950.0 * t.abs() };
    let modes = [
        ("exact", MhMode::Exact),
        ("austerity", MhMode::approx(0.05, 400)),
        ("barker", MhMode::barker(1.0, 400)),
        ("confidence", MhMode::confidence(0.05, 400)),
    ];
    for (name, mode) in modes {
        let mut rng = Pcg64::new(3, 9);
        let mut scratch = MhScratch::new(model.n());
        // pre-warm capacities a long confidence/exhaustion decision could
        // otherwise grow mid-measurement
        scratch.idx_buf.reserve(model.n());
        scratch.trace.reserve(64);
        let mut cur = 0.45f64;
        let mut cache = model.init_cache(&cur);
        for _ in 0..200 {
            let p = kernel.propose(&cur, &mut rng);
            mh_step_cached(&model, &mut cur, &mut cache, p, &mode, &mut scratch, &mut rng);
        }

        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..300 {
            let p = kernel.propose(&cur, &mut rng);
            mh_step_cached(&model, &mut cur, &mut cache, p, &mode, &mut scratch, &mut rng);
        }
        let delta = ALLOCS.load(Ordering::SeqCst) - before;
        assert_eq!(delta, 0, "rule {name}: {delta} heap allocations on the cached hot path");
    }

    // ---- phase 2: the parallel exact scan allocates nothing inside the
    // workers (uncached and cached), after warmup ----
    let model = LinRegModel::new(linreg_toy(20_000, 1), 3.0, 4950.0).unwrap();
    let worker_allocs = AtomicU64::new(0);
    let evals = AtomicU64::new(0);
    let (cur, prop) = (0.44f64, 0.46f64);
    let mut scan = ScanScratch::new(4, model.n());
    let eval = |a: usize, b: usize| {
        let before = tl_allocs();
        let m = model.lldiff_range_moments(a, b, &cur, &prop);
        worker_allocs.fetch_add(tl_allocs() - before, Ordering::Relaxed);
        evals.fetch_add(1, Ordering::Relaxed);
        m
    };
    // warmup (sizes the per-chunk partials buffer), then measured scans
    let want = full_scan_moments_par(model.n(), &mut scan, eval);
    worker_allocs.store(0, Ordering::SeqCst);
    for _ in 0..3 {
        let got = full_scan_moments_par(model.n(), &mut scan, eval);
        assert_eq!(got.0.to_bits(), want.0.to_bits());
    }
    assert!(evals.load(Ordering::SeqCst) > 0);
    assert_eq!(
        worker_allocs.load(Ordering::SeqCst),
        0,
        "uncached parallel-scan workers allocated on the steady state"
    );

    // cached variant: the chunk kernels write through the cache lanes;
    // warm the cache first, then the scan must stay allocation-free
    // inside the kernels. (The kernel-side counter lives in the model's
    // chunk evaluator's thread, measured across the whole scan via the
    // global counter minus the coordinator's thread-spawn cost — so
    // instead we assert on a serial cached scan, where the only thread
    // is this one and the global counter applies.)
    let mut serial_scan = ScanScratch::new(1, model.n());
    let mut cache = model.init_cache(&cur);
    model.begin_step(&mut cache);
    let _ = model.cached_full_scan(&mut cache, &prop, &mut serial_scan); // warmup
    model.end_step(&mut cache, &prop, false);
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..3 {
        model.begin_step(&mut cache);
        let got = model.cached_full_scan(&mut cache, &prop, &mut serial_scan);
        assert_eq!(got.0.to_bits(), want.0.to_bits());
        model.end_step(&mut cache, &prop, false);
    }
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(delta, 0, "serial cached full scan allocated {delta} times in steady state");
}

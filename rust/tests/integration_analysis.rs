//! Integration of the §5 analysis stack against real sequential tests:
//! the DP error/usage predictions must match Monte-Carlo measurements on
//! actual data populations, and the design machinery must order methods
//! the way Fig. 6 reports.

use austerity::coordinator::austerity::{seq_mh_test, SeqTestConfig};
use austerity::coordinator::delta::{exact_accept_prob, SeqTestTable};
use austerity::coordinator::dp::analyze_pocock;
use austerity::coordinator::scheduler::MinibatchScheduler;
use austerity::exp::population::{harvest_pairs, mnist_like_model, FixedLs};
use austerity::stats::Pcg64;

#[test]
fn dp_predicts_real_test_error_and_usage() {
    // The Gaussian-random-walk DP is an *approximation* (CLT across
    // stages); verify it against real sequential tests on a real
    // logistic l-population, as in Figs. 1 and 10.
    let n = 12_214;
    let m = 500;
    let eps = 0.05;
    let model = mnist_like_model(n, 42);
    let pop = &harvest_pairs(&model, 0.01, 1, 5, 3)[0];
    let sqrt_n1 = ((n - 1) as f64).sqrt();
    let trials = 3_000;

    for mu_std in [0.5f64, 1.5, 3.0] {
        let mu0 = pop.mu - mu_std * pop.sigma_l / sqrt_n1;
        let cfg = SeqTestConfig::new(eps, m);
        let fixed = FixedLs(&pop.ls);
        let mut sched = MinibatchScheduler::new(n).unwrap();
        let mut rng = Pcg64::new(50, mu_std.to_bits());
        let (mut wrong, mut used) = (0usize, 0u64);
        for _ in 0..trials {
            let o = seq_mh_test(&fixed, &(), &(), mu0, &cfg, &mut sched, &mut rng);
            wrong += (!o.accept) as usize; // truth: mu > mu0
            used += o.n_used as u64;
        }
        let sim_err = wrong as f64 / trials as f64;
        let sim_pi = used as f64 / (trials as f64 * n as f64);
        let dp = analyze_pocock(mu_std, m, n, eps, 256);
        let err_tol = 3.0 * (dp.error * (1.0 - dp.error) / trials as f64).sqrt() + 0.015;
        assert!(
            (sim_err - dp.error).abs() < err_tol,
            "mu_std {mu_std}: sim {sim_err} dp {} (tol {err_tol})",
            dp.error
        );
        assert!(
            (sim_pi - dp.expected_pi).abs() < 0.08,
            "mu_std {mu_std}: sim pi {sim_pi} dp {}",
            dp.expected_pi
        );
    }
}

#[test]
fn table_interpolation_matches_measured_acceptance() {
    // P_{a,eps} = Pa + Delta from the table must match the measured
    // acceptance frequency of the real sequential test (Fig. 12).
    let n = 12_214;
    let m = 500;
    let eps = 0.05;
    let model = mnist_like_model(n, 42);
    let pops = harvest_pairs(&model, 0.01, 5, 3, 9);
    let table = SeqTestTable::build(m, n, eps, 12.0, 21, 128);
    let cfg = SeqTestConfig::new(eps, m);
    let trials = 800;

    for pop in &pops {
        let stats = pop.stats();
        let pa_pred = austerity::coordinator::delta::approx_accept_prob(n, &stats, &table, 24);
        let fixed = FixedLs(&pop.ls);
        let mut sched = MinibatchScheduler::new(n).unwrap();
        let mut rng = Pcg64::seeded(stats.mu.to_bits());
        let mut acc = 0usize;
        for _ in 0..trials {
            let u = rng.uniform_pos();
            let mu0 = (u.ln() + pop.log_correction) / n as f64;
            let o = seq_mh_test(&fixed, &(), &(), mu0, &cfg, &mut sched, &mut rng);
            acc += o.accept as usize;
        }
        let measured = acc as f64 / trials as f64;
        assert!(
            (pa_pred - measured).abs() < 0.08,
            "predicted {pa_pred} measured {measured} (exact {})",
            exact_accept_prob(n, &stats)
        );
    }
}

#[test]
fn epsilon_sweep_monotone_in_data_usage_on_real_chain() {
    // Across the approximate chain as a whole, larger eps must not use
    // more data (the knob works end-to-end).
    use austerity::coordinator::{run_chain, Budget, MhMode};
    use austerity::samplers::GaussianRandomWalk;

    let model = mnist_like_model(8_000, 1);
    let init = model.map_estimate(50);
    let kernel = GaussianRandomWalk::new(0.01, 10.0);
    let mut fractions = Vec::new();
    for eps in [0.01, 0.05, 0.2] {
        let mut rng = Pcg64::seeded(2);
        let (_, stats) = run_chain(
            &model,
            &kernel,
            &MhMode::approx(eps, 400),
            init.clone(),
            Budget::Steps(300),
            0,
            1,
            |_| 0.0,
            &mut rng,
        );
        fractions.push(stats.mean_data_fraction(8_000));
    }
    assert!(
        fractions[0] >= fractions[1] && fractions[1] >= fractions[2],
        "{fractions:?}"
    );
}

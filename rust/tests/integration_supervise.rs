//! Supervision integration suite: the self-healing layer of the engine.
//!
//! Four pillars, matching DESIGN.md §Supervision layer:
//!
//! 1. **Supervised retry** — a chain downed by a worker panic is
//!    restarted from its last good checkpoint under a `RetryPolicy`,
//!    and the recovered chain's draws are bit-identical to a run that
//!    never failed (the checkpoint captures the PCG stream and the
//!    scheduler position exactly).
//! 2. **Checkpoint integrity** — torn writes, flipped bits and short
//!    reads on generation files are caught by the CRC32-sealed v3
//!    framing; resume falls back generation by generation and stamps
//!    the fallback as `ChainStatus::Recovered`.
//! 3. **Stall watchdog + quorum** — a chain frozen past `stall_after`
//!    is flagged `Stalled`; when the healthy fraction drops below
//!    `min_chains`, the launch aborts with `LaunchError::QuorumLost`.
//! 4. **Typed launch errors** — a manifest describing a different
//!    launch refuses the resume up front, and the resume/checkpoint
//!    flag pairing is enforced at build time.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use austerity::coordinator::{
    current_chain_step, Budget, ChainRun, ChainStatus, CkptError, KernelSession, LaunchError,
    MhMode, RetryPolicy, Sample, Session, StepOutcome, TransitionKernel,
};
use austerity::stats::Pcg64;
use austerity::testkit::fault::{FaultKind, FaultyModel, FaultyStore, StoreFault};
use austerity::testkit::models::ConjugateGaussian;

static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Fresh per-test checkpoint directory under the system temp dir.
fn scratch_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "austerity_supervise_{tag}_{}_{}",
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

fn bits(samples: &[Sample]) -> Vec<u64> {
    samples.iter().map(|s| s.value.to_bits()).collect()
}

/// Chain-by-chain equality of draws (bitwise) and every counter the
/// checkpoint carries; wall time and `ckpt_failures` are per-run.
fn assert_runs_identical(a: &[ChainRun], b: &[ChainRun], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: chain count");
    for (ra, rb) in a.iter().zip(b) {
        let c = ra.chain;
        assert_eq!(ra.chain, rb.chain, "{label}");
        assert_eq!(ra.stats.steps, rb.stats.steps, "{label} chain {c}: steps");
        assert_eq!(ra.stats.accepted, rb.stats.accepted, "{label} chain {c}: accepted");
        assert_eq!(ra.stats.data_used, rb.stats.data_used, "{label} chain {c}: data_used");
        assert_eq!(ra.stats.guard_trips, rb.stats.guard_trips, "{label} chain {c}: guard_trips");
        assert_eq!(bits(&ra.samples), bits(&rb.samples), "{label} chain {c}: draws");
    }
}

fn test_model() -> ConjugateGaussian {
    ConjugateGaussian::synthetic(900, 0.3, 1.0, 0.0, 2.0, 7)
}

// ---------------------------------------------------------------------
// 1. supervised retry
// ---------------------------------------------------------------------

/// Acceptance test (a): a chain that crashes once mid-run, is retried
/// under a `RetryPolicy` and resumes from its last checkpoint produces
/// draws bit-identical to the same-seed run that never faulted.
#[test]
fn retried_chain_is_bit_identical_to_a_fault_free_run() {
    let bare = test_model();
    let proposal = bare.rw_proposal(0.4);
    let clean = Session::new(&bare)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(2)
        .seed(21)
        .budget(Budget::Steps(40))
        .init(0.0)
        .run();
    assert_eq!(clean.failed_chains(), 0);

    // chain 1 panics the first time it executes step 17 — after the
    // generation-1 checkpoint at step 10 — then replays clean
    let faulty = FaultyModel::new(test_model()).fault_once(1, 17, FaultKind::Panic);
    let dir = scratch_dir("retry_bitident");
    let report = Session::new(&faulty)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(2)
        .seed(21)
        .budget(Budget::Steps(40))
        .checkpoint_every(10)
        .checkpoint_dir(dir.clone())
        .retry(RetryPolicy::retries(1))
        .init(0.0)
        .run();
    assert_eq!(report.failed_chains(), 0, "the retry must absorb the crash");
    assert_eq!(
        report.statuses[1],
        ChainStatus::Recovered { retries: 1 },
        "got {:?}",
        report.statuses[1]
    );
    assert_eq!(report.statuses[0], ChainStatus::Completed);
    assert_eq!(report.recovered_chains(), 1);
    assert_runs_identical(&report.runs, &clean.runs, "supervised retry");
    let json = report.to_json();
    assert!(json.contains("\"recovered_chains\":1"), "{json}");
    assert!(json.contains("\"status\":\"recovered\""), "{json}");
    assert!(json.contains("\"retries\":1"), "{json}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A launch without checkpointing still retries — the restarted attempt
/// replays from scratch (more expensive, still bit-identical).
#[test]
fn retry_without_checkpoints_replays_from_scratch() {
    let bare = test_model();
    let proposal = bare.rw_proposal(0.4);
    let clean = Session::new(&bare)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(2)
        .seed(5)
        .budget(Budget::Steps(30))
        .init(0.0)
        .run();
    let faulty = FaultyModel::new(test_model()).fault_once(0, 6, FaultKind::Panic);
    let report = Session::new(&faulty)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(2)
        .seed(5)
        .budget(Budget::Steps(30))
        .retry(RetryPolicy::new(2, Duration::from_millis(1)))
        .init(0.0)
        .run();
    assert_eq!(report.failed_chains(), 0);
    assert_eq!(report.statuses[0], ChainStatus::Recovered { retries: 1 });
    assert_runs_identical(&report.runs, &clean.runs, "scratch replay");
}

/// A persistent fault exhausts the retry budget: the chain stays
/// `Failed` and the reason records the burned retries.
#[test]
fn exhausted_retries_surface_as_failed_with_the_attempt_count() {
    let faulty = FaultyModel::new(test_model()).fault(0, 5, FaultKind::Panic);
    let proposal = test_model().rw_proposal(0.4);
    let report = Session::new(&faulty)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(2)
        .seed(3)
        .budget(Budget::Steps(20))
        .retry(RetryPolicy::retries(2))
        .init(0.0)
        .run();
    assert_eq!(report.failed_chains(), 1);
    match &report.statuses[0] {
        ChainStatus::Failed { step, reason } => {
            assert_eq!(*step, 5);
            assert!(reason.contains("injected fault"), "reason: {reason}");
            assert!(reason.contains("after 2 retries"), "reason: {reason}");
        }
        s => panic!("chain 0 should have failed, got {s:?}"),
    }
    assert_eq!(report.statuses[1], ChainStatus::Completed);
}

// ---------------------------------------------------------------------
// 2. checkpoint integrity under I/O faults
// ---------------------------------------------------------------------

/// Acceptance test (b): the newest generation of a chain is torn on
/// disk (truncated write that still reported success); resume falls
/// back to the previous generation silently, completes, and stamps the
/// chain `Recovered` — with draws bit-identical to an uninterrupted run.
#[test]
fn resume_falls_back_past_a_torn_newest_generation() {
    let model = test_model();
    let proposal = model.rw_proposal(0.4);
    let dir = scratch_dir("torn_gen");
    let launch = |budget: usize| {
        Session::new(&model)
            .kernel(&proposal)
            .rule(MhMode::approx(0.05, 64))
            .chains(2)
            .seed(13)
            .budget(Budget::Steps(budget))
            .checkpoint_every(10)
            .checkpoint_dir(dir.clone())
    };
    let full = Session::new(&model)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(2)
        .seed(13)
        .budget(Budget::Steps(80))
        .init(0.0)
        .run();
    // partial run: generations 1..=4 per chain (default retain keeps 3
    // and 4); chain 0's generation 4 is torn at byte 12 — the write
    // "succeeds", the file is garbage
    let torn = FaultyStore::new().fault(0, 4, StoreFault::TruncateAt(12));
    let partial = launch(40).checkpoint_store(torn.into_arc()).init(0.0).run();
    assert_eq!(partial.failed_chains(), 0, "a torn write is silent at write time");
    let resumed = launch(80).resume_from(dir.clone()).init(0.0).run();
    assert_eq!(resumed.failed_chains(), 0);
    assert_eq!(
        resumed.statuses[0],
        ChainStatus::Recovered { retries: 1 },
        "chain 0 must fall back one generation, got {:?}",
        resumed.statuses[0]
    );
    assert_eq!(resumed.statuses[1], ChainStatus::Completed, "chain 1's files are intact");
    assert_runs_identical(&resumed.runs, &full.runs, "torn-generation fallback");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Silent media corruption (one flipped bit) is caught by the CRC32
/// trailer at load time; resume falls back to the previous generation.
#[test]
fn resume_falls_back_past_a_flipped_bit() {
    let model = test_model();
    let proposal = model.rw_proposal(0.4);
    let dir = scratch_dir("flip_bit");
    let launch = |budget: usize| {
        Session::new(&model)
            .kernel(&proposal)
            .rule(MhMode::approx(0.05, 64))
            .chains(1)
            .seed(17)
            .budget(Budget::Steps(budget))
            .checkpoint_every(10)
            .checkpoint_dir(dir.clone())
    };
    let full = Session::new(&model)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(1)
        .seed(17)
        .budget(Budget::Steps(80))
        .init(0.0)
        .run();
    let partial = launch(40).init(0.0).run();
    assert_eq!(partial.failed_chains(), 0);
    // the corruption happens on the read path at resume time: byte 60
    // of generation 4 comes back with one bit flipped
    let flipped = FaultyStore::new().fault(0, 4, StoreFault::FlipBit(60));
    let resumed =
        launch(80).checkpoint_store(flipped.into_arc()).resume_from(dir.clone()).init(0.0).run();
    assert_eq!(resumed.failed_chains(), 0);
    assert_eq!(resumed.statuses[0], ChainStatus::Recovered { retries: 1 });
    assert_runs_identical(&resumed.runs, &full.runs, "flipped-bit fallback");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A checkpoint write failing outright (injected ENOSPC) is non-fatal:
/// the chain keeps sampling on its previous generation and the failure
/// is counted in `ckpt_failures`.
#[test]
fn checkpoint_write_failure_is_counted_and_nonfatal() {
    let model = test_model();
    let proposal = model.rw_proposal(0.4);
    let dir = scratch_dir("enospc");
    let store = FaultyStore::new().fault(0, 2, StoreFault::Enospc);
    let report = Session::new(&model)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(2)
        .seed(9)
        .budget(Budget::Steps(40))
        .checkpoint_every(10)
        .checkpoint_dir(dir.clone())
        .checkpoint_store(store.into_arc())
        .init(0.0)
        .run();
    assert_eq!(report.failed_chains(), 0, "ENOSPC on one generation must not down the chain");
    assert_eq!(report.statuses[0], ChainStatus::Completed);
    let chain0 = report.runs.iter().find(|r| r.chain == 0).expect("chain 0 completed");
    assert_eq!(chain0.stats.ckpt_failures, 1, "exactly the scripted write fails");
    assert_eq!(report.merged.ckpt_failures, 1);
    let json = report.to_json();
    assert!(json.contains("\"ckpt_failures\":1"), "{json}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// 3. stall watchdog + quorum
// ---------------------------------------------------------------------

/// A trivial random-walk kernel that freezes one scripted (chain, step)
/// long enough for the watchdog to notice, then finishes normally.
struct SleepyKernel {
    slow_chain: usize,
    sleep_at: usize,
    sleep: Duration,
}

impl TransitionKernel for SleepyKernel {
    type State = f64;
    type Scratch = ();

    fn scratch(&self, _init: &f64) -> Self::Scratch {}

    fn step(&self, state: &mut f64, _scratch: &mut (), rng: &mut Pcg64) -> StepOutcome {
        let (chain, step) = current_chain_step();
        if chain == self.slow_chain && step == self.sleep_at {
            std::thread::sleep(self.sleep);
        }
        *state += rng.normal();
        StepOutcome { accepted: true, data_used: 1, guard_trips: 0 }
    }
}

/// A chain frozen inside a step past `stall_after` is flagged — and the
/// flag is sticky even though the chain later limps to completion.
#[test]
fn watchdog_flags_a_chain_frozen_past_the_stall_window() {
    let kernel = SleepyKernel {
        slow_chain: 1,
        sleep_at: 10,
        sleep: Duration::from_millis(400),
    };
    let report = KernelSession::new(&kernel)
        .label("sleepy")
        .chains(2)
        .seed(4)
        .budget(Budget::Steps(20))
        .stall_after(Duration::from_millis(50))
        .init(0.0)
        .run();
    assert_eq!(report.failed_chains(), 0);
    assert_eq!(report.stalled_chains(), 1);
    assert_eq!(
        report.statuses[1],
        ChainStatus::Stalled { step: 10 },
        "got {:?}",
        report.statuses[1]
    );
    assert_eq!(report.statuses[0], ChainStatus::Completed);
    // a stalled-but-finished chain still delivered its full budget
    assert_eq!(report.merged.steps, 2 * 20);
    let json = report.to_json();
    assert!(json.contains("\"stalled_chains\":1"), "{json}");
    assert!(json.contains("\"status\":\"stalled\""), "{json}");
}

/// With a full quorum demanded, one stalled chain drops the healthy
/// fraction below `min_chains`: the launch aborts with the typed
/// `LaunchError::QuorumLost` instead of returning a thin report.
#[test]
fn quorum_loss_aborts_the_launch_with_a_typed_error() {
    let kernel = SleepyKernel {
        slow_chain: 0,
        sleep_at: 5,
        sleep: Duration::from_millis(900),
    };
    let result = KernelSession::new(&kernel)
        .label("sleepy")
        .chains(2)
        .seed(8)
        .budget(Budget::Steps(1_000_000))
        .stall_after(Duration::from_millis(40))
        .min_chains(1.0)
        .init(0.0)
        .try_run();
    match result {
        Err(LaunchError::QuorumLost { healthy, required, stalled, chains, .. }) => {
            assert_eq!(chains, 2);
            assert_eq!(required, 2);
            assert!(healthy < required, "healthy {healthy} < required {required}");
            assert!(stalled >= 1, "the sleeping chain must be flagged");
            let msg = format!("{}", LaunchError::QuorumLost {
                healthy,
                required,
                failed: 0,
                stalled,
                chains,
            });
            assert!(msg.contains("quorum lost"), "message: {msg}");
        }
        Ok(_) => panic!("quorum loss must abort the launch"),
        Err(e) => panic!("wrong error flavour: {e}"),
    }
}

// ---------------------------------------------------------------------
// 4. typed launch errors and flag pairing
// ---------------------------------------------------------------------

/// Resuming into a directory whose manifest describes a different
/// launch (here: a different base seed) is refused up front with a
/// typed `CkptError::ManifestMismatch` — before any sampling happens.
#[test]
fn manifest_mismatch_refuses_the_resume() {
    let model = test_model();
    let proposal = model.rw_proposal(0.4);
    let dir = scratch_dir("manifest");
    let launch = |seed: u64| {
        Session::new(&model)
            .kernel(&proposal)
            .rule(MhMode::approx(0.05, 64))
            .chains(2)
            .seed(seed)
            .budget(Budget::Steps(30))
            .checkpoint_every(10)
            .checkpoint_dir(dir.clone())
            .init(0.0)
    };
    launch(11).run();
    let result = launch(12).resume_from(dir.clone()).try_run();
    match result {
        Err(LaunchError::Resume(CkptError::ManifestMismatch(what))) => {
            assert!(what.contains("base_seed"), "detail: {what}");
        }
        Ok(_) => panic!("a mismatched manifest must refuse the resume"),
        Err(e) => panic!("wrong error flavour: {e}"),
    }
    // the same-seed launch still resumes fine afterwards: refusing the
    // resume must not have damaged the directory
    let resumed = launch(11).resume_from(dir.clone()).run();
    assert_eq!(resumed.failed_chains(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// `.resume_from` without the checkpoint flags is a configuration bug,
/// caught at build time with a message naming the missing pair.
#[test]
#[should_panic(expected = "requires .checkpoint_every")]
fn resume_without_checkpoint_pairing_panics_at_build_time() {
    let model = test_model();
    let proposal = model.rw_proposal(0.4);
    let _ = Session::new(&model)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(1)
        .seed(1)
        .budget(Budget::Steps(10))
        .resume_from(scratch_dir("unpaired"))
        .init(0.0)
        .run();
}

/// The supervision counters all surface in the report JSON even on a
/// plain, fault-free launch (zero-valued, but present for dashboards).
#[test]
fn report_json_carries_the_supervision_counters() {
    let model = test_model();
    let proposal = model.rw_proposal(0.4);
    let report = Session::new(&model)
        .kernel(&proposal)
        .rule(MhMode::approx(0.05, 64))
        .chains(2)
        .seed(2)
        .budget(Budget::Steps(20))
        .init(0.0)
        .run();
    let json = report.to_json();
    for key in [
        "\"failed_chains\":0",
        "\"recovered_chains\":0",
        "\"stalled_chains\":0",
        "\"ckpt_failures\":0",
        "\"guard_trips\":",
        "\"status\":\"completed\"",
    ] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
}

//! Integration tests for the columnar (SoA) likelihood backend and the
//! deterministic intra-step parallel full scan:
//!
//! * parallel full-scan moments are bit-identical across `threads =
//!   1/2/8` and equal to the serial chunked scan, for the uncached and
//!   cached paths of both SoA models;
//! * the lane-blocked SoA kernels agree with the retained row-major
//!   scalar reference (`lldiff_moments_ref`) to ≤ 1e-12 relative error
//!   on random logistic/linreg instances;
//! * the gathered and range kernels are bit-identical on the same index
//!   sets (the contract `ExactTest`'s range-based scan rests on);
//! * at the engine level, a K = 1 exact-rule launch with spare workers
//!   (`threads > chains` ⇒ intra-step parallel scans) reproduces the
//!   single-threaded launch bit for bit;
//! * the persistent executor keeps all of the above: scans pinned to
//!   explicit pools of 1/2/8 workers reproduce the serial bits (cached
//!   and uncached), and a deliberately oversubscribed launch (4 chains ×
//!   4 scan spans on a 2-worker pool) completes with the same bits as
//!   the single-threaded launch.

use austerity::coordinator::engine::{run_engine, run_engine_cached, EngineConfig};
use austerity::coordinator::Budget;
use austerity::coordinator::Executor;
use austerity::coordinator::MhMode;
use austerity::data::synthetic::{linreg_toy, two_class_gaussian};
use austerity::models::traits::{full_scan_moments_par, CachedLlDiff, LlDiffModel, ScanScratch};
use austerity::models::{LinRegModel, LogisticModel};
use austerity::samplers::{GaussianRandomWalk, ScalarRandomWalk};
use austerity::stats::Pcg64;

fn logistic(n: usize) -> LogisticModel {
    LogisticModel::new(two_class_gaussian(n, 12, 1.2, 3), 10.0).unwrap()
}

fn linreg(n: usize) -> LinRegModel {
    LinRegModel::new(linreg_toy(n, 0), 3.0, 4950.0).unwrap()
}

#[test]
fn parallel_scan_bit_identical_across_thread_counts_logistic() {
    // population deliberately not a multiple of the chunk or lane size
    let model = logistic(5 * 512 + 391);
    let mut rng = Pcg64::seeded(1);
    let cur: Vec<f64> = (0..12).map(|_| 0.2 * rng.normal()).collect();
    let prop: Vec<f64> = (0..12).map(|_| 0.2 * rng.normal()).collect();
    let serial = model.full_moments(&cur, &prop);
    for threads in [1usize, 2, 8] {
        let mut scan = ScanScratch::new(threads, model.n());
        let par = full_scan_moments_par(model.n(), &mut scan, |a, b| {
            model.lldiff_range_moments(a, b, &cur, &prop)
        });
        assert_eq!(par.0.to_bits(), serial.0.to_bits(), "threads {threads}");
        assert_eq!(par.1.to_bits(), serial.1.to_bits(), "threads {threads}");

        // cached scan: same bits from a cold cache and a warm cache
        let mut cache = model.init_cache(&cur);
        model.begin_step(&mut cache);
        let cold = model.cached_full_scan(&mut cache, &prop, &mut scan);
        assert_eq!(cold.0.to_bits(), serial.0.to_bits(), "cached cold threads {threads}");
        assert_eq!(cold.1.to_bits(), serial.1.to_bits(), "cached cold threads {threads}");
        model.end_step(&mut cache, &prop, false);
        model.begin_step(&mut cache);
        let warm = model.cached_full_scan(&mut cache, &prop, &mut scan);
        assert_eq!(warm.0.to_bits(), serial.0.to_bits(), "cached warm threads {threads}");
    }
}

#[test]
fn parallel_scan_bit_identical_across_thread_counts_linreg() {
    let model = linreg(4 * 512 + 77);
    let serial = model.full_moments(&0.44, &0.46);
    for threads in [1usize, 2, 8] {
        let mut scan = ScanScratch::new(threads, model.n());
        let par = full_scan_moments_par(model.n(), &mut scan, |a, b| {
            model.lldiff_range_moments(a, b, &0.44, &0.46)
        });
        assert_eq!(par.0.to_bits(), serial.0.to_bits(), "threads {threads}");
        assert_eq!(par.1.to_bits(), serial.1.to_bits(), "threads {threads}");

        let mut cache = model.init_cache(&0.44);
        model.begin_step(&mut cache);
        let cached = model.cached_full_scan(&mut cache, &0.46, &mut scan);
        assert_eq!(cached.0.to_bits(), serial.0.to_bits(), "cached threads {threads}");
        assert_eq!(cached.1.to_bits(), serial.1.to_bits(), "cached threads {threads}");
    }
}

#[test]
fn soa_kernels_agree_with_scalar_reference() {
    let model = logistic(3_000);
    let toy = linreg(10_000);
    let mut rng = Pcg64::seeded(4);
    for trial in 0..24 {
        let cur: Vec<f64> = (0..12).map(|_| 0.3 * rng.normal()).collect();
        let prop: Vec<f64> = cur.iter().map(|t| t + 0.05 * rng.normal()).collect();
        let k = rng.below(800) + 1;
        let idx: Vec<u32> = (0..k).map(|_| rng.below(3_000) as u32).collect();
        let (s, s2) = model.lldiff_moments(&idx, &cur, &prop);
        let (rs, rs2) = model.lldiff_moments_ref(&idx, &cur, &prop);
        assert!(
            (s - rs).abs() <= 1e-12 * rs.abs().max(1.0),
            "logistic trial {trial}: {s} vs {rs}"
        );
        assert!(
            (s2 - rs2).abs() <= 1e-12 * rs2.abs().max(1.0),
            "logistic trial {trial}: {s2} vs {rs2}"
        );

        let tc = rng.normal_scaled(0.3, 0.2);
        let tp = rng.normal_scaled(0.3, 0.2);
        let lidx: Vec<u32> = (0..k).map(|_| rng.below(10_000) as u32).collect();
        let (ls, ls2) = toy.lldiff_moments(&lidx, &tc, &tp);
        let (lrs, lrs2) = toy.lldiff_moments_ref(&lidx, tc, tp);
        assert!(
            (ls - lrs).abs() <= 1e-12 * lrs.abs().max(1.0),
            "linreg trial {trial}: {ls} vs {lrs}"
        );
        assert!(
            (ls2 - lrs2).abs() <= 1e-12 * lrs2.abs().max(1.0),
            "linreg trial {trial}: {ls2} vs {lrs2}"
        );
    }
}

#[test]
fn gathered_and_range_kernels_share_bits() {
    let model = logistic(2_000);
    let mut rng = Pcg64::seeded(5);
    let cur: Vec<f64> = (0..12).map(|_| 0.2 * rng.normal()).collect();
    let prop: Vec<f64> = (0..12).map(|_| 0.2 * rng.normal()).collect();
    for _ in 0..16 {
        let a = rng.below(1_500);
        let b = a + rng.below(500) + 1;
        let idx: Vec<u32> = (a as u32..b as u32).collect();
        let g = model.lldiff_moments(&idx, &cur, &prop);
        let r = model.lldiff_range_moments(a, b, &cur, &prop);
        assert_eq!(g.0.to_bits(), r.0.to_bits(), "[{a}, {b})");
        assert_eq!(g.1.to_bits(), r.1.to_bits(), "[{a}, {b})");
    }
}

#[test]
fn engine_exact_rule_identical_with_spare_intra_step_workers() {
    // K = 1 chain, threads ∈ {1, 4, 8}: threads > chains hands the chain
    // intra-step scan workers; samples must not change by a bit.
    let model = logistic(4_000);
    let init = model.map_estimate(30);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    let launch = |threads: usize, cached: bool| {
        let cfg = EngineConfig::new(1, 77, Budget::Steps(60)).threads(threads);
        let res = if cached {
            run_engine_cached(&model, &kernel, &MhMode::Exact, init.clone(), &cfg, |_c| {
                |t: &Vec<f64>| t[0]
            })
        } else {
            run_engine(&model, &kernel, &MhMode::Exact, init.clone(), &cfg, |_c| {
                |t: &Vec<f64>| t[0]
            })
        };
        res.runs[0].samples.iter().map(|s| s.value.to_bits()).collect::<Vec<u64>>()
    };
    let base = launch(1, false);
    assert_eq!(base.len(), 60);
    for threads in [4usize, 8] {
        assert_eq!(launch(threads, false), base, "uncached threads {threads}");
        assert_eq!(launch(threads, true), base, "cached threads {threads}");
    }
    assert_eq!(launch(1, true), base, "cached serial");
}

#[test]
fn engine_exact_rule_identical_with_spare_workers_linreg_cached() {
    let model = linreg(6_000);
    let kernel = ScalarRandomWalk { sigma: 0.004, log_prior: |t: f64| -4950.0 * t.abs() };
    let launch = |threads: usize| {
        let cfg = EngineConfig::new(2, 13, Budget::Steps(50)).threads(threads);
        let res = run_engine_cached(&model, &kernel, &MhMode::Exact, 0.45f64, &cfg, |_c| {
            |t: &f64| *t
        });
        res.runs
            .iter()
            .map(|r| r.samples.iter().map(|s| s.value.to_bits()).collect::<Vec<u64>>())
            .collect::<Vec<_>>()
    };
    let base = launch(2); // one worker per chain, no spare
    for threads in [1usize, 6, 9] {
        assert_eq!(launch(threads), base, "threads {threads}");
    }
}

#[test]
fn executor_scan_bit_identical_across_pool_sizes() {
    // span width (4) deliberately differs from the pool sizes, so spans
    // multiplex on the small pools and sit idle-capacity on the large
    // one — the bits must not care either way.
    let model = logistic(6 * 512 + 201);
    let mut rng = Pcg64::seeded(21);
    let cur: Vec<f64> = (0..12).map(|_| 0.2 * rng.normal()).collect();
    let prop: Vec<f64> = (0..12).map(|_| 0.2 * rng.normal()).collect();
    let serial = model.full_moments(&cur, &prop);
    for pool_workers in [1usize, 2, 8] {
        let pool = Executor::new(pool_workers);
        let mut scan = ScanScratch::on_pool(&pool, 4, model.n());
        let par = full_scan_moments_par(model.n(), &mut scan, |a, b| {
            model.lldiff_range_moments(a, b, &cur, &prop)
        });
        assert_eq!(par.0.to_bits(), serial.0.to_bits(), "pool {pool_workers}");
        assert_eq!(par.1.to_bits(), serial.1.to_bits(), "pool {pool_workers}");

        // cached == uncached == serial on the same pool
        let mut cache = model.init_cache(&cur);
        model.begin_step(&mut cache);
        let cached = model.cached_full_scan(&mut cache, &prop, &mut scan);
        assert_eq!(cached.0.to_bits(), serial.0.to_bits(), "cached pool {pool_workers}");
        assert_eq!(cached.1.to_bits(), serial.1.to_bits(), "cached pool {pool_workers}");
    }
}

#[test]
fn engine_oversubscribed_pool_completes_deterministically() {
    // 4 chains, each granted 4 intra-step scan spans (threads = 16), all
    // pinned to a pool of only 2 background workers: 4 + 16 logical
    // tasks multiplex over 2 threads plus the helping submitters. The
    // launch must complete (no deadlock) with the bits of the
    // single-threaded run.
    let model = logistic(4_000);
    let init = model.map_estimate(30);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    let launch = |cfg: EngineConfig| {
        let res = run_engine(&model, &kernel, &MhMode::Exact, init.clone(), &cfg, |_c| {
            |t: &Vec<f64>| t[0]
        });
        assert_eq!(res.failed_chains(), 0);
        res.runs
            .iter()
            .map(|r| r.samples.iter().map(|s| s.value.to_bits()).collect::<Vec<u64>>())
            .collect::<Vec<_>>()
    };
    let base = launch(EngineConfig::new(4, 5, Budget::Steps(30)).threads(1));
    let pooled = launch(
        EngineConfig::new(4, 5, Budget::Steps(30))
            .threads(16)
            .executor(Executor::new(2)),
    );
    assert_eq!(pooled, base);
}

//! Same-seed bit-identity oracle for the `Session` front-end.
//!
//! The API redesign demoted the five legacy launch entry points
//! (`run_engine`, `run_engine_cached`, `run_engine_kernel`, `run_chain`,
//! `run_chain_cached`) to internal shims behind `Session` /
//! `KernelSession`. These tests are the reason they still exist: every
//! front-end launch must replay the corresponding legacy path **bit for
//! bit** under the same seed — exact, austerity and confidence rules
//! (plus Barker on the engine path), cached and uncached, multi-chain
//! and single-chain.

use austerity::coordinator::engine::{
    run_engine, run_engine_cached, run_engine_kernel, EngineConfig, STREAM_BASE,
};
use austerity::coordinator::{
    run_chain, run_chain_cached, AcceptanceTest, Budget, ChainRun, KernelSession, MhMode, Param,
    Sample, Session, Thinned,
};
use austerity::data::synthetic::{linreg_toy, two_class_gaussian};
use austerity::models::traits::Proposal;
use austerity::models::{LinRegModel, LlDiffModel, LogisticModel};
use austerity::samplers::sgld::{SgldConfig, SgldKernel};
use austerity::samplers::GaussianRandomWalk;
use austerity::stats::Pcg64;
use austerity::testkit::models::ConjugateGaussian;

fn bits(samples: &[Sample]) -> Vec<u64> {
    samples.iter().map(|s| s.value.to_bits()).collect()
}

/// Chain-by-chain equality of draws (bitwise) and counters.
fn assert_runs_identical(a: &[ChainRun], b: &[ChainRun], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: chain count");
    for (ra, rb) in a.iter().zip(b) {
        assert_eq!(ra.chain, rb.chain, "{label}");
        assert_eq!(ra.stats.steps, rb.stats.steps, "{label} chain {}", ra.chain);
        assert_eq!(ra.stats.accepted, rb.stats.accepted, "{label} chain {}", ra.chain);
        assert_eq!(ra.stats.data_used, rb.stats.data_used, "{label} chain {}", ra.chain);
        assert_eq!(bits(&ra.samples), bits(&rb.samples), "{label} chain {}", ra.chain);
    }
}

fn mh_modes(batch: usize) -> Vec<MhMode> {
    vec![
        MhMode::Exact,
        MhMode::approx(0.05, batch),
        MhMode::confidence(0.05, batch),
        MhMode::barker(1.0, batch),
    ]
}

#[test]
fn session_replays_cached_engine_bitwise_for_every_rule() {
    let model = LogisticModel::new(two_class_gaussian(1_200, 5, 1.2, 0), 10.0).unwrap();
    let init = model.map_estimate(40);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    for mode in mh_modes(100) {
        let cfg = EngineConfig::new(3, 42, Budget::Steps(120)).burn_in(10).thin(2);
        let legacy =
            run_engine_cached(&model, &kernel, &mode, init.clone(), &cfg, |_c| {
                |t: &Vec<f64>| t[0]
            });
        let report = Session::new(&model)
            .kernel(&kernel)
            .rule(mode.clone())
            .chains(3)
            .seed(42)
            .budget(Budget::Steps(120))
            .burn_in(10)
            .thin(2)
            .record(Param::index(0))
            .init(init.clone())
            .run();
        assert_eq!(report.backend, "cached", "logistic model rides the cached path");
        assert_runs_identical(&report.runs, &legacy.runs, &format!("cached {mode:?}"));

        // cross-path oracle: the uncached legacy launch makes the same
        // decisions (the CachedLlDiff contract), so the Session output
        // is pinned against both engines at once.
        let uncached =
            run_engine(&model, &kernel, &mode, init.clone(), &cfg, |_c| |t: &Vec<f64>| t[0]);
        assert_runs_identical(&report.runs, &uncached.runs, &format!("uncached {mode:?}"));
    }
}

#[test]
fn session_replays_uncached_engine_for_conjugate_gaussian() {
    let model = ConjugateGaussian::synthetic(900, 0.3, 1.0, 0.0, 2.0, 7);
    let proposal = model.rw_proposal(0.4);
    for mode in mh_modes(64) {
        let cfg = EngineConfig::new(2, 11, Budget::Steps(150)).burn_in(20);
        let legacy = run_engine(&model, &proposal, &mode, 0.0f64, &cfg, |_c| |p: &f64| *p);
        let report = Session::new(&model)
            .kernel(&proposal)
            .rule(mode.clone())
            .chains(2)
            .seed(11)
            .budget(Budget::Steps(150))
            .burn_in(20)
            .init(0.0)
            .run();
        assert_eq!(report.backend, "uncached");
        assert_eq!(report.rule, mode.name());
        assert_runs_identical(&report.runs, &legacy.runs, &format!("{mode:?}"));
    }
}

#[test]
fn single_chain_session_replays_run_chain_and_cached_variant() {
    let model = LinRegModel::new(linreg_toy(2_000, 0), 3.0, 4950.0).unwrap();
    let kernel = |cur: &f64, rng: &mut Pcg64| Proposal {
        param: cur + rng.normal_scaled(0.0, 0.005),
        log_correction: 0.0,
    };
    for mode in [MhMode::Exact, MhMode::approx(0.05, 200), MhMode::confidence(0.05, 200)] {
        let run_legacy = |cached: bool| {
            // chain 0 of a seed-5 launch steps on stream STREAM_BASE
            let mut rng = Pcg64::new(5, STREAM_BASE);
            if cached {
                run_chain_cached(
                    &model,
                    &kernel,
                    &mode,
                    0.45f64,
                    Budget::Steps(100),
                    5,
                    3,
                    |&p| p,
                    &mut rng,
                )
            } else {
                run_chain(
                    &model,
                    &kernel,
                    &mode,
                    0.45f64,
                    Budget::Steps(100),
                    5,
                    3,
                    |&p| p,
                    &mut rng,
                )
            }
        };
        let (samples_cached, stats_cached) = run_legacy(true);
        let (samples_uncached, stats_uncached) = run_legacy(false);
        let report = Session::new(&model)
            .kernel(&kernel)
            .rule(mode.clone())
            .chains(1)
            .seed(5)
            .budget(Budget::Steps(100))
            .burn_in(5)
            .thin(3)
            .init(0.45)
            .run();
        assert_eq!(report.backend, "cached", "linreg model rides the cached path");
        assert_eq!(report.runs.len(), 1);
        assert_eq!(report.merged.steps, stats_cached.steps);
        assert_eq!(report.merged.accepted, stats_cached.accepted);
        assert_eq!(report.merged.data_used, stats_cached.data_used);
        assert_eq!(bits(&report.runs[0].samples), bits(&samples_cached), "{mode:?}");
        // and the uncached single-chain path agrees bit for bit too
        assert_eq!(bits(&samples_cached), bits(&samples_uncached), "{mode:?}");
        assert_eq!(stats_cached.accepted, stats_uncached.accepted);
    }
}

#[test]
fn kernel_session_replays_run_engine_kernel() {
    let model = LinRegModel::new(linreg_toy(2_000, 0), 3.0, 4950.0).unwrap();
    let kernel = SgldKernel {
        model: &model,
        cfg: SgldConfig { alpha: 5e-6, grad_batch: 50, correction: None },
    };
    let cfg = EngineConfig::new(2, 9, Budget::Steps(300)).burn_in(30);
    let legacy = run_engine_kernel(&kernel, 0.45f64, &cfg, |_c| |t: &f64| *t);
    let report = KernelSession::new(&kernel)
        .label("sgld")
        .data_size(model.n())
        .chains(2)
        .seed(9)
        .budget(Budget::Steps(300))
        .burn_in(30)
        .init(0.45)
        .run();
    assert_eq!(report.backend, "kernel");
    assert_eq!(report.rule, "sgld");
    assert_runs_identical(&report.runs, &legacy.runs, "sgld");
    let frac_gap = report.mean_data_fraction() - legacy.merged.mean_data_fraction(model.n());
    assert!(frac_gap.abs() < 1e-15, "frac gap {frac_gap}");
}

#[test]
fn data_budget_runs_surface_consumption_in_report_and_json() {
    let model = LogisticModel::new(two_class_gaussian(1_000, 5, 1.2, 0), 10.0).unwrap();
    let init = model.map_estimate(40);
    let kernel = GaussianRandomWalk::new(0.02, 10.0);
    let budget = 40 * model.n() as u64; // 40 full-scan equivalents per chain
    let report = Session::new(&model)
        .kernel(&kernel)
        .rule(MhMode::approx(0.05, 100))
        .chains(2)
        .seed(13)
        .budget(Budget::Data(budget))
        .init(init)
        .run();
    // the budget axis is datapoint evaluations: consumed amount is
    // reported and the consumed fraction covers the target (the step
    // crossing the budget completes, so slightly over 1 is fine)
    assert!(report.merged.data_used >= 2 * budget);
    let consumed = report.budget_consumed();
    assert!(consumed >= 1.0 && consumed < 1.5, "consumed {consumed}");
    assert!(report.data_per_sec() > 0.0);
    let frac = report.mean_data_fraction();
    assert!(frac > 0.0 && frac <= 1.0, "frac {frac}");
    let json = report.to_json();
    for key in [
        "\"budget\":{\"kind\":\"data\"",
        "\"consumed_fraction\":",
        "\"data_used\":",
        "\"data_per_sec\":",
        "\"rule\":\"austerity\"",
        "\"backend\":\"cached\"",
    ] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
}

#[test]
fn record_library_matches_scalar_stream() {
    let model = ConjugateGaussian::synthetic(600, -0.2, 1.0, 0.0, 2.0, 3);
    let proposal = model.rw_proposal(0.4);
    let session = || {
        Session::new(&model)
            .kernel(&proposal)
            .chains(2)
            .seed(21)
            .budget(Budget::Steps(90))
            .burn_in(10)
    };
    // Param::all keeps one full vector per retained draw, whose first
    // component is exactly the recorded scalar stream
    let full = session().record(Param::all()).init(0.1).run();
    for (run, obs) in full.runs.iter().zip(&full.observers) {
        assert_eq!(obs.draws().len(), run.samples.len());
        for (draw, sample) in obs.draws().iter().zip(&run.samples) {
            assert_eq!(draw.len(), 1);
            assert_eq!(draw[0].to_bits(), sample.value.to_bits());
        }
    }
    // the default recorder is Param::index(0): same draws, same bits
    let default_run = session().init(0.1).run();
    assert_runs_identical(&default_run.runs, &full.runs, "default vs Param::all");
    // Thinned keeps every 2nd retained draw in the inner observer
    let thinned = session().record(Thinned::new(Param::all(), 2)).init(0.1).run();
    for (run, obs) in thinned.runs.iter().zip(&thinned.observers) {
        assert_eq!(obs.inner().draws().len(), run.samples.len().div_ceil(2));
    }
}

//! Paper §6.1: random-walk MH on a logistic-regression posterior with an
//! epsilon sweep — the risk/variance trade-off of Fig. 2 in miniature,
//! including the three-layer PJRT backend if artifacts are built.
//!
//! Run: make artifacts && cargo run --release --example logistic_regression

use austerity::coordinator::{mh_step, MhMode, MhScratch};
use austerity::metrics::PredictiveMean;
use austerity::models::traits::ProposalKernel;
use austerity::models::{LlDiffModel, LogisticModel};
use austerity::runtime::{PjrtLogistic, PjrtRuntime};
use austerity::samplers::GaussianRandomWalk;
use austerity::stats::Pcg64;

fn run_eps<M: LlDiffModel<Param = Vec<f64>>>(
    model: &M,
    test: &LogisticModel,
    init: &[f64],
    eps: f64,
    steps: usize,
) -> (Vec<f64>, f64, f64) {
    let kernel = GaussianRandomWalk::new(0.01, 10.0);
    let mode = MhMode::approx(eps, 500);
    let mut scratch = MhScratch::new(model.n());
    let mut rng = Pcg64::seeded(7);
    let mut cur = init.to_vec();
    let mut pm = PredictiveMean::new(test.n());
    let mut used = 0u64;
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let prop = kernel.propose(&cur, &mut rng);
        let info = mh_step(model, &mut cur, prop, &mode, &mut scratch, &mut rng);
        used += info.n_used as u64;
        if step >= steps / 5 {
            let probs: Vec<f64> =
                (0..test.n()).map(|i| test.predict(test.data().row(i), &cur)).collect();
            pm.add(&probs);
        }
    }
    (
        pm.mean(),
        used as f64 / (steps as f64 * model.n() as f64),
        steps as f64 / t0.elapsed().as_secs_f64(),
    )
}

fn main() {
    let model = austerity::exp::population::mnist_like_model(12_214, 42);
    let test = austerity::exp::population::mnist_like_model(500, 43);
    let init = model.map_estimate(80);
    let steps = 1_500;

    // ground truth: exact chain, 4x the steps
    let (truth, _, _) = run_eps(&model, &test, &init, 0.0, steps * 4);

    println!("eps    risk(pred-mean)   data/test   steps/s");
    for eps in [0.0, 0.01, 0.05, 0.1, 0.2] {
        let (est, frac, sps) = run_eps(&model, &test, &init, eps, steps);
        let risk: f64 = est
            .iter()
            .zip(&truth)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / est.len() as f64;
        println!("{eps:<5}  {risk:>12.3e}    {frac:>7.3}    {sps:>7.0}");
    }

    // same chain served by the AOT Pallas kernel through PJRT
    if PjrtRuntime::default_dir().join("manifest.txt").exists() {
        let rt = PjrtRuntime::new(&PjrtRuntime::default_dir()).expect("runtime");
        let pjrt = PjrtLogistic::new(&model, rt).expect("backend");
        let (_, frac, sps) = run_eps(&pjrt, &test, &init, 0.05, 100);
        println!("\npjrt backend (eps=0.05): data/test {frac:.3}, {sps:.0} steps/s");
    } else {
        println!("\n(run `make artifacts` to also exercise the PJRT backend)");
    }
}

//! Paper §6.1: random-walk MH on a logistic-regression posterior with an
//! epsilon sweep — the risk/variance trade-off of Fig. 2 in miniature,
//! run through the `Session` front-end (cached fast path picked
//! automatically for the native model), including the three-layer PJRT
//! backend if artifacts are built.
//!
//! Run: make artifacts && cargo run --release --example logistic_regression

use austerity::coordinator::{Budget, MhMode, Session, VecMean};
use austerity::models::{LlDiffModel, LogisticModel};
use austerity::runtime::{PjrtLogistic, PjrtRuntime};
use austerity::samplers::GaussianRandomWalk;

/// One epsilon: run 2 chains, stream the held-out predictive panel into
/// a per-chain `VecMean`, merge, and report (estimate, data fraction,
/// steps/sec).
fn run_eps<M>(
    model: &M,
    test: &LogisticModel,
    init: &[f64],
    eps: f64,
    steps: usize,
) -> (Vec<f64>, f64, f64)
where
    M: LlDiffModel<Param = Vec<f64>> + Sync,
{
    let kernel = GaussianRandomWalk::new(0.01, 10.0);
    let chains = 2usize;
    let per_chain = (steps / chains).max(1);
    let report = Session::new(model)
        .kernel(&kernel)
        .rule(MhMode::approx(eps, 500))
        .chains(chains)
        .seed(7)
        .budget(Budget::Steps(per_chain))
        .burn_in(per_chain / 5)
        .record_with(|_c| {
            VecMean::new(test.n(), |theta: &Vec<f64>| {
                (0..test.n())
                    .map(|i| test.predict(test.data().row(i), theta))
                    .collect()
            })
        })
        .init(init.to_vec())
        .run();
    let pm = VecMean::merged(&report.observers);
    (pm.mean(), report.mean_data_fraction(), report.steps_per_sec())
}

fn main() {
    let model = austerity::exp::population::mnist_like_model(12_214, 42);
    let test = austerity::exp::population::mnist_like_model(500, 43);
    let init = model.map_estimate(80);
    let steps = 1_500;

    // ground truth: exact chains, 4x the steps
    let (truth, _, _) = run_eps(&model, &test, &init, 0.0, steps * 4);

    println!("eps    risk(pred-mean)   data/test   steps/s");
    for eps in [0.0, 0.01, 0.05, 0.1, 0.2] {
        let (est, frac, sps) = run_eps(&model, &test, &init, eps, steps);
        let risk: f64 = est
            .iter()
            .zip(&truth)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / est.len() as f64;
        println!("{eps:<5}  {risk:>12.3e}    {frac:>7.3}    {sps:>7.0}");
    }

    // same chain served by the AOT Pallas kernel through PJRT
    if PjrtRuntime::available() && PjrtRuntime::default_dir().join("manifest.txt").exists() {
        let rt = PjrtRuntime::new(&PjrtRuntime::default_dir()).expect("runtime");
        let pjrt = PjrtLogistic::new(&model, rt).expect("backend");
        let (_, frac, sps) = run_eps(&pjrt, &test, &init, 0.05, 100);
        println!("\npjrt backend (eps=0.05): data/test {frac:.3}, {sps:.0} steps/s");
    } else {
        println!("\n(run `make artifacts` to also exercise the PJRT backend)");
    }
}

//! Paper §6.1: random-walk MH on a logistic-regression posterior with an
//! epsilon sweep — the risk/variance trade-off of Fig. 2 in miniature,
//! run on the parallel multi-chain engine, including the three-layer
//! PJRT backend if artifacts are built.
//!
//! Run: make artifacts && cargo run --release --example logistic_regression

use austerity::coordinator::{run_engine, Budget, ChainObserver, EngineConfig, MhMode};
use austerity::metrics::PredictiveMean;
use austerity::models::{LlDiffModel, LogisticModel};
use austerity::runtime::{PjrtLogistic, PjrtRuntime};
use austerity::samplers::GaussianRandomWalk;

/// Per-chain predictive-mean accumulator over a held-out panel.
struct PmObs<'a> {
    test: &'a LogisticModel,
    pm: PredictiveMean,
}

impl<'a> ChainObserver<Vec<f64>> for PmObs<'a> {
    fn observe(&mut self, theta: &Vec<f64>) -> f64 {
        let probs: Vec<f64> = (0..self.test.n())
            .map(|i| self.test.predict(self.test.data().row(i), theta))
            .collect();
        self.pm.add(&probs);
        0.0
    }
}

fn run_eps<M>(
    model: &M,
    test: &LogisticModel,
    init: &[f64],
    eps: f64,
    steps: usize,
) -> (Vec<f64>, f64, f64)
where
    M: LlDiffModel<Param = Vec<f64>> + Sync,
{
    let kernel = GaussianRandomWalk::new(0.01, 10.0);
    let mode = MhMode::approx(eps, 500);
    let chains = 2usize;
    let per_chain = (steps / chains).max(1);
    let cfg = EngineConfig::new(chains, 7, Budget::Steps(per_chain)).burn_in(per_chain / 5);
    let t0 = std::time::Instant::now();
    let res = run_engine(model, &kernel, &mode, init.to_vec(), &cfg, |_c| PmObs {
        test,
        pm: PredictiveMean::new(test.n()),
    });
    let secs = t0.elapsed().as_secs_f64();
    let mut pm = PredictiveMean::new(test.n());
    for o in &res.observers {
        pm.merge(&o.pm);
    }
    (
        pm.mean(),
        res.merged.data_used as f64 / (res.merged.steps as f64 * model.n() as f64),
        res.merged.steps as f64 / secs,
    )
}

fn main() {
    let model = austerity::exp::population::mnist_like_model(12_214, 42);
    let test = austerity::exp::population::mnist_like_model(500, 43);
    let init = model.map_estimate(80);
    let steps = 1_500;

    // ground truth: exact chains, 4x the steps
    let (truth, _, _) = run_eps(&model, &test, &init, 0.0, steps * 4);

    println!("eps    risk(pred-mean)   data/test   steps/s");
    for eps in [0.0, 0.01, 0.05, 0.1, 0.2] {
        let (est, frac, sps) = run_eps(&model, &test, &init, eps, steps);
        let risk: f64 = est
            .iter()
            .zip(&truth)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / est.len() as f64;
        println!("{eps:<5}  {risk:>12.3e}    {frac:>7.3}    {sps:>7.0}");
    }

    // same chain served by the AOT Pallas kernel through PJRT
    if PjrtRuntime::available() && PjrtRuntime::default_dir().join("manifest.txt").exists() {
        let rt = PjrtRuntime::new(&PjrtRuntime::default_dir()).expect("runtime");
        let pjrt = PjrtLogistic::new(&model, rt).expect("backend");
        let (_, frac, sps) = run_eps(&pjrt, &test, &init, 0.05, 100);
        println!("\npjrt backend (eps=0.05): data/test {frac:.3}, {sps:.0} steps/s");
    } else {
        println!("\n(run `make artifacts` to also exercise the PJRT backend)");
    }
}

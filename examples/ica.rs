//! Paper §6.2: posterior sampling of an ICA unmixing matrix on the
//! Stiefel manifold, exact vs approximate MH, measured by the Amari
//! distance to the true unmixing matrix.
//!
//! Run: cargo run --release --example ica [-- N]

use austerity::coordinator::{run_chain, Budget, MhMode};
use austerity::data::synthetic::ica_mixture;
use austerity::models::ica::amari_distance;
use austerity::models::{IcaModel, LlDiffModel};
use austerity::samplers::StiefelRandomWalk;
use austerity::stats::welford::Welford;
use austerity::stats::Pcg64;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(195_000);
    println!("mixing 4 sources into N = {n} observations ...");
    let (obs, w0) = ica_mixture(n, 3);
    let model = IcaModel::new(obs);
    let kernel = StiefelRandomWalk::new(0.03);

    let steps = 600;
    println!("\neps    E[amari]  +-      accept  data/test  steps/s");
    for eps in [0.0, 0.01, 0.05, 0.1] {
        let mode = MhMode::approx(eps, 600);
        let mut rng = Pcg64::seeded(4);
        let t0 = std::time::Instant::now();
        let w0c = w0.clone();
        let (samples, stats) = run_chain(
            &model,
            &kernel,
            &mode,
            w0.clone(),
            Budget::Steps(steps),
            steps / 5,
            1,
            move |w| amari_distance(w, &w0c),
            &mut rng,
        );
        let secs = t0.elapsed().as_secs_f64();
        let mut w = Welford::new();
        for s in &samples {
            w.add(s.value);
        }
        println!(
            "{eps:<5}  {:.4}   {:.4}  {:.2}    {:.3}      {:.1}",
            w.mean(),
            w.std_sample(),
            stats.acceptance_rate(),
            stats.mean_data_fraction(model.n()),
            steps as f64 / secs
        );
    }
    println!(
        "\nthe approximate chains explore the same posterior while touching \
         a fraction of the {n} points per decision"
    );
}

//! Paper §6.2: posterior sampling of an ICA unmixing matrix on the
//! Stiefel manifold, exact vs approximate MH, measured by the Amari
//! distance to the true unmixing matrix. Chains run in parallel on the
//! multi-chain engine.
//!
//! Run: cargo run --release --example ica [-- N]

use austerity::coordinator::{run_engine, Budget, EngineConfig, MhMode};
use austerity::data::synthetic::ica_mixture;
use austerity::data::Mat;
use austerity::models::ica::amari_distance;
use austerity::models::{IcaModel, LlDiffModel};
use austerity::samplers::StiefelRandomWalk;
use austerity::stats::welford::Welford;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(195_000);
    println!("mixing 4 sources into N = {n} observations ...");
    let (obs, w0) = ica_mixture(n, 3);
    let model = IcaModel::new(obs);
    let kernel = StiefelRandomWalk::new(0.03);

    let chains = 2;
    let steps_per_chain = 300;
    println!("\neps    E[amari]  +-      accept  data/test  steps/s  R-hat");
    for eps in [0.0, 0.01, 0.05, 0.1] {
        let mode = MhMode::approx(eps, 600);
        let t0 = std::time::Instant::now();
        let cfg = EngineConfig::new(chains, 4, Budget::Steps(steps_per_chain))
            .burn_in(steps_per_chain / 5);
        let res = run_engine(&model, &kernel, &mode, w0.clone(), &cfg, |_c| {
            let w0c = w0.clone();
            move |w: &Mat| amari_distance(w, &w0c)
        });
        let secs = t0.elapsed().as_secs_f64();
        let mut w = Welford::new();
        for run in &res.runs {
            for s in &run.samples {
                w.add(s.value);
            }
        }
        println!(
            "{eps:<5}  {:.4}   {:.4}  {:.2}    {:.3}      {:.1}    {:.3}",
            w.mean(),
            w.std_sample(),
            res.merged.acceptance_rate(),
            res.merged.mean_data_fraction(model.n()),
            res.merged.steps as f64 / secs,
            res.convergence.rhat,
        );
    }
    println!(
        "\nthe approximate chains explore the same posterior while touching \
         a fraction of the {n} points per decision"
    );
}

//! Paper §6.2: posterior sampling of an ICA unmixing matrix on the
//! Stiefel manifold, exact vs approximate MH, measured by the Amari
//! distance to the true unmixing matrix. Chains run in parallel through
//! the `Session` front-end.
//!
//! Run: cargo run --release --example ica [-- N]

use austerity::coordinator::{Budget, MhMode, ScalarFn, Session};
use austerity::data::synthetic::ica_mixture;
use austerity::data::Mat;
use austerity::models::ica::amari_distance;
use austerity::models::IcaModel;
use austerity::samplers::StiefelRandomWalk;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(195_000);
    println!("mixing 4 sources into N = {n} observations ...");
    let (obs, w0) = ica_mixture(n, 3);
    let model = IcaModel::new(obs);
    let kernel = StiefelRandomWalk::new(0.03);

    let chains = 2;
    let steps_per_chain = 300;
    println!("\neps    E[amari]  +-      accept  data/test  steps/s  R-hat");
    for eps in [0.0, 0.01, 0.05, 0.1] {
        let w0c = w0.clone();
        let report = Session::new(&model)
            .kernel(&kernel)
            .rule(MhMode::approx(eps, 600))
            .chains(chains)
            .seed(4)
            .budget(Budget::Steps(steps_per_chain))
            .burn_in(steps_per_chain / 5)
            .record(ScalarFn::new(move |w: &Mat| amari_distance(w, &w0c)))
            .init(w0.clone())
            .run();
        println!(
            "{eps:<5}  {:.4}   {:.4}  {:.2}    {:.3}      {:.1}    {:.3}",
            report.pooled_mean(),
            report.pooled_std(),
            report.acceptance_rate(),
            report.mean_data_fraction(),
            report.steps_per_sec(),
            report.rhat(),
        );
    }
    println!(
        "\nthe approximate chains explore the same posterior while touching \
         a fraction of the {n} points per decision"
    );
}

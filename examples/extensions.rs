//! The beyond-the-paper extensions in one tour:
//!   1. adaptive epsilon (paper §7 future work): anneal the bias knob
//!   2. the pseudo-marginal baseline the paper argues against (§4)
//!   3. multi-valued Gibbs via Gumbel-max tournaments (supp. F extension)
//!
//! Run: cargo run --release --example extensions

use austerity::coordinator::adaptive::{run_adaptive_chain, EpsSchedule};
use austerity::coordinator::{run_engine_cached, Budget, EngineConfig, MhMode};
use austerity::models::{LlDiffModel, PottsModel};
use austerity::samplers::gibbs_potts::{potts_sweep, PottsMode, PottsScratch, PottsStats};
use austerity::samplers::pseudo_marginal::{run_pseudo_marginal, PoissonEstimator};
use austerity::samplers::GaussianRandomWalk;
use austerity::stats::Pcg64;

fn main() {
    let model = austerity::exp::population::mnist_like_model(12_214, 42);
    let init = model.map_estimate(60);
    let kernel = GaussianRandomWalk::new(0.01, model.prior_precision);

    // ---- 1. adaptive epsilon --------------------------------------------
    println!("1. adaptive epsilon (eps_t ~ t^-1/2, floor 0.005)");
    for (label, sched) in [
        ("fixed 0.01", EpsSchedule::Fixed(0.01)),
        ("fixed 0.1 ", EpsSchedule::Fixed(0.1)),
        ("annealed  ", EpsSchedule::default_anneal()),
    ] {
        let mut rng = Pcg64::seeded(1);
        let (_, stats) = run_adaptive_chain(
            &model, &kernel, &sched, 500, init.clone(),
            Budget::Steps(2_000), 200, 1, |t| t[0], &mut rng,
        );
        println!(
            "   {label}: data/test {:.3}, accept {:.2}",
            stats.mean_data_fraction(model.n()),
            stats.acceptance_rate()
        );
    }

    // ---- 2. pseudo-marginal baseline ------------------------------------
    println!("\n2. pseudo-marginal (Poisson estimator) vs sequential test");
    let est = PoissonEstimator { batch: 100, lambda: 3.0, center: 0.0 };
    let mut rng = Pcg64::seeded(2);
    let pm = run_pseudo_marginal(&model, &kernel, &est, init.clone(), 400, &mut rng, |_| {});
    let seq_res = run_engine_cached(
        &model,
        &kernel,
        &MhMode::approx(0.05, 500),
        init,
        &EngineConfig::new(1, 2, Budget::Steps(400)),
        |_c| |_: &Vec<f64>| 0.0,
    );
    let seq = seq_res.merged;
    println!(
        "   pseudo-marginal: accept {:.2}, longest stuck run {} steps, {:.0}% estimates clamped",
        pm.accepted as f64 / pm.steps as f64,
        pm.longest_stuck,
        100.0 * pm.clamped as f64 / pm.steps as f64,
    );
    println!(
        "   sequential test: accept {:.2} — exact-but-stuck vs biased-but-mixing (paper §4)",
        seq.acceptance_rate()
    );

    // ---- 3. multi-valued Gibbs ------------------------------------------
    println!("\n3. K=3 Potts Gibbs via Gumbel-max tournaments of sequential tests");
    let potts = PottsModel::random(60, 3, 0.03, 7);
    for (label, mode) in [
        ("exact      ", PottsMode::Exact),
        ("approx e=.1", PottsMode::Approx { eps: 0.1, batch: 300 }),
    ] {
        let mut rng = Pcg64::seeded(3);
        let mut x: Vec<usize> = (0..60).map(|_| rng.below(3)).collect();
        let mut scratch = PottsScratch::new(&potts);
        let mut stats = PottsStats::default();
        let t0 = std::time::Instant::now();
        for _ in 0..50 {
            potts_sweep(&potts, &mut x, &mode, &mut scratch, &mut stats, &mut rng);
        }
        println!(
            "   {label}: {:.1} sweeps/s, {:.0} pair-evals/update",
            50.0 / t0.elapsed().as_secs_f64(),
            stats.pairs_used as f64 / stats.updates as f64
        );
    }
}

//! The beyond-the-paper extensions in one tour — every one a
//! `TransitionKernel` driven through the session front-end:
//!   1. adaptive epsilon (paper §7 future work): anneal the bias knob
//!   2. the pseudo-marginal baseline the paper argues against (§4)
//!   3. multi-valued Gibbs via Gumbel-max tournaments (supp. F extension)
//!
//! Run: cargo run --release --example extensions

use austerity::coordinator::adaptive::{AdaptiveMhKernel, EpsSchedule};
use austerity::coordinator::{Budget, KernelSession, MhMode, ScalarFn, Session};
use austerity::models::{LlDiffModel, PottsModel};
use austerity::samplers::gibbs_potts::{PottsMode, PottsSweepKernel};
use austerity::samplers::pseudo_marginal::{PmKernel, PmPathology, PoissonEstimator};
use austerity::samplers::GaussianRandomWalk;
use austerity::stats::Pcg64;

fn main() {
    let model = austerity::exp::population::mnist_like_model(12_214, 42);
    let init = model.map_estimate(60);
    let kernel = GaussianRandomWalk::new(0.01, model.prior_precision);

    // ---- 1. adaptive epsilon --------------------------------------------
    println!("1. adaptive epsilon (eps_t ~ t^-1/2, floor 0.005)");
    for (label, sched) in [
        ("fixed 0.01", EpsSchedule::Fixed(0.01)),
        ("fixed 0.1 ", EpsSchedule::Fixed(0.1)),
        ("annealed  ", EpsSchedule::default_anneal()),
    ] {
        let adaptive =
            AdaptiveMhKernel { model: &model, proposal: &kernel, schedule: &sched, batch: 500 };
        let report = KernelSession::new(&adaptive)
            .label("adaptive")
            .data_size(model.n())
            .seed(1)
            .budget(Budget::Steps(2_000))
            .burn_in(200)
            .record(ScalarFn::new(|t: &Vec<f64>| t[0]))
            .init(init.clone())
            .run();
        println!(
            "   {label}: data/test {:.3}, accept {:.2}",
            report.mean_data_fraction(),
            report.acceptance_rate()
        );
    }

    // ---- 2. pseudo-marginal baseline ------------------------------------
    println!("\n2. pseudo-marginal (Poisson estimator) vs sequential test");
    let est = PoissonEstimator { batch: 100, lambda: 3.0, center: 0.0 };
    let pm_kernel = PmKernel::new(&model, &kernel, &est, init.clone());
    let pm_res = KernelSession::new(&pm_kernel)
        .label("pseudo-marginal")
        .data_size(model.n())
        .seed(2)
        .budget(Budget::Steps(400))
        .record_with(|_c| PmPathology::default())
        .init(pm_kernel.init_state())
        .run();
    let path = &pm_res.observers[0];
    let seq_res = Session::new(&model)
        .kernel(&kernel)
        .rule(MhMode::approx(0.05, 500))
        .seed(2)
        .budget(Budget::Steps(400))
        .record(ScalarFn::new(|_: &Vec<f64>| 0.0))
        .init(init)
        .run();
    println!(
        "   pseudo-marginal: accept {:.2}, longest stuck run {} steps, {:.0}% estimates clamped",
        pm_res.acceptance_rate(),
        path.longest_stuck,
        100.0 * path.clamped as f64 / pm_res.merged.steps as f64,
    );
    println!(
        "   sequential test: accept {:.2} — exact-but-stuck vs biased-but-mixing (paper §4)",
        seq_res.acceptance_rate()
    );

    // ---- 3. multi-valued Gibbs ------------------------------------------
    println!("\n3. K=3 Potts Gibbs via Gumbel-max tournaments of sequential tests");
    let potts = PottsModel::random(60, 3, 0.03, 7);
    let mut rng = Pcg64::seeded(3);
    let x0: Vec<usize> = (0..60).map(|_| rng.below(3)).collect();
    for (label, mode) in [
        ("exact      ", PottsMode::Exact),
        ("approx e=.1", PottsMode::Approx { eps: 0.1, batch: 300 }),
    ] {
        let sweep_kernel = PottsSweepKernel { model: &potts, mode };
        let report = KernelSession::new(&sweep_kernel)
            .label("potts")
            .chains(2)
            .seed(3)
            .budget(Budget::Steps(25))
            .record(ScalarFn::new(|x: &Vec<usize>| {
                x.iter().filter(|&&s| s == 0).count() as f64 / x.len() as f64
            }))
            .init(x0.clone())
            .run();
        println!(
            "   {label}: {:.1} sweeps/s, {:.0} pair-evals/update",
            report.steps_per_sec(),
            report.merged.data_used as f64 / (report.merged.steps * potts.d()) as f64,
        );
    }
}

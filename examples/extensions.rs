//! The beyond-the-paper extensions in one tour — every one a
//! `TransitionKernel` on the multi-chain engine:
//!   1. adaptive epsilon (paper §7 future work): anneal the bias knob
//!   2. the pseudo-marginal baseline the paper argues against (§4)
//!   3. multi-valued Gibbs via Gumbel-max tournaments (supp. F extension)
//!
//! Run: cargo run --release --example extensions

use austerity::coordinator::adaptive::{run_adaptive_chain, EpsSchedule};
use austerity::coordinator::{run_engine_cached, run_engine_kernel, Budget, EngineConfig, MhMode};
use austerity::models::{LlDiffModel, PottsModel};
use austerity::samplers::gibbs_potts::{PottsMode, PottsSweepKernel};
use austerity::samplers::pseudo_marginal::{PmKernel, PmPathology, PoissonEstimator};
use austerity::samplers::GaussianRandomWalk;
use austerity::stats::Pcg64;

fn main() {
    let model = austerity::exp::population::mnist_like_model(12_214, 42);
    let init = model.map_estimate(60);
    let kernel = GaussianRandomWalk::new(0.01, model.prior_precision);

    // ---- 1. adaptive epsilon --------------------------------------------
    println!("1. adaptive epsilon (eps_t ~ t^-1/2, floor 0.005)");
    for (label, sched) in [
        ("fixed 0.01", EpsSchedule::Fixed(0.01)),
        ("fixed 0.1 ", EpsSchedule::Fixed(0.1)),
        ("annealed  ", EpsSchedule::default_anneal()),
    ] {
        let mut rng = Pcg64::seeded(1);
        let (_, stats) = run_adaptive_chain(
            &model, &kernel, &sched, 500, init.clone(),
            Budget::Steps(2_000), 200, 1, |t| t[0], &mut rng,
        );
        println!(
            "   {label}: data/test {:.3}, accept {:.2}",
            stats.mean_data_fraction(model.n()),
            stats.acceptance_rate()
        );
    }

    // ---- 2. pseudo-marginal baseline ------------------------------------
    println!("\n2. pseudo-marginal (Poisson estimator) vs sequential test");
    let est = PoissonEstimator { batch: 100, lambda: 3.0, center: 0.0 };
    let pm_kernel = PmKernel::new(&model, &kernel, &est, init.clone());
    let pm_res = run_engine_kernel(
        &pm_kernel,
        pm_kernel.init_state(),
        &EngineConfig::new(1, 2, Budget::Steps(400)),
        |_c| PmPathology::default(),
    );
    let pm = &pm_res.merged;
    let path = &pm_res.observers[0];
    let seq_res = run_engine_cached(
        &model,
        &kernel,
        &MhMode::approx(0.05, 500),
        init,
        &EngineConfig::new(1, 2, Budget::Steps(400)),
        |_c| |_: &Vec<f64>| 0.0,
    );
    let seq = seq_res.merged;
    println!(
        "   pseudo-marginal: accept {:.2}, longest stuck run {} steps, {:.0}% estimates clamped",
        pm.acceptance_rate(),
        path.longest_stuck,
        100.0 * path.clamped as f64 / pm.steps as f64,
    );
    println!(
        "   sequential test: accept {:.2} — exact-but-stuck vs biased-but-mixing (paper §4)",
        seq.acceptance_rate()
    );

    // ---- 3. multi-valued Gibbs ------------------------------------------
    println!("\n3. K=3 Potts Gibbs via Gumbel-max tournaments of sequential tests");
    let potts = PottsModel::random(60, 3, 0.03, 7);
    let mut rng = Pcg64::seeded(3);
    let x0: Vec<usize> = (0..60).map(|_| rng.below(3)).collect();
    for (label, mode) in [
        ("exact      ", PottsMode::Exact),
        ("approx e=.1", PottsMode::Approx { eps: 0.1, batch: 300 }),
    ] {
        let sweep_kernel = PottsSweepKernel { model: &potts, mode };
        let res = run_engine_kernel(
            &sweep_kernel,
            x0.clone(),
            &EngineConfig::new(2, 3, Budget::Steps(25)),
            |_c| |x: &Vec<usize>| x.iter().filter(|&&s| s == 0).count() as f64 / x.len() as f64,
        );
        println!(
            "   {label}: {:.1} sweeps/s, {:.0} pair-evals/update",
            res.steps_per_sec(),
            res.merged.data_used as f64 / (res.merged.steps * potts.d()) as f64,
        );
    }
}

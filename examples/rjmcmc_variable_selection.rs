//! Paper §6.3: Bayesian variable selection by reversible-jump MCMC on a
//! MiniBooNE-like synthetic dataset — exact vs approximate MH tests,
//! reporting the recovered support and model size.
//!
//! Run: cargo run --release --example rjmcmc_variable_selection

use austerity::coordinator::{run_chain, Budget, MhMode};
use austerity::data::synthetic::sparse_logistic;
use austerity::models::rjlogistic::{RjLogisticModel, RjState};
use austerity::models::LlDiffModel;
use austerity::samplers::RjKernel;
use austerity::stats::Pcg64;

fn main() {
    let n = 40_000;
    let d = 21;
    let (ds, beta_true) = sparse_logistic(n, d, 5, 0.28, 31);
    let truly_active: Vec<usize> = (1..d).filter(|&j| beta_true[j] != 0.0).collect();
    println!("N = {n}, D = {d}, true support {truly_active:?}");

    let model = RjLogisticModel::new(ds, 1e-10);
    let steps = 20_000;

    for (label, mode) in [
        ("exact ", MhMode::Exact),
        ("approx", MhMode::approx(0.05, 500)),
    ] {
        let kernel = RjKernel::new(&model);
        let mut rng = Pcg64::seeded(9);
        let mut incl = vec![0u64; d];
        let mut ks = 0u64;
        let mut count = 0u64;
        let t0 = std::time::Instant::now();
        let (_, stats) = run_chain(
            &model,
            &kernel,
            &mode,
            RjState::with_active(d, &[0], &[-0.9]),
            Budget::Steps(steps),
            steps / 5,
            1,
            |s| {
                for &j in &s.active {
                    incl[j] += 1;
                }
                ks += s.k() as u64;
                count += 1;
                0.0
            },
            &mut rng,
        );
        let secs = t0.elapsed().as_secs_f64();
        let mut top: Vec<(usize, f64)> = (1..d)
            .map(|j| (j, incl[j] as f64 / count as f64))
            .collect();
        top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let picked: Vec<usize> = top.iter().take(5).map(|(j, _)| *j).collect();
        let hit = picked.iter().filter(|j| truly_active.contains(j)).count();
        println!(
            "{label}: top-5 features {picked:?} ({hit}/5 correct) | mean k {:.1} | \
             accept {:.2} | data/test {:.3} | {:.0} steps/s",
            ks as f64 / count as f64,
            stats.acceptance_rate(),
            stats.mean_data_fraction(model.n()),
            steps as f64 / secs
        );
    }
}

//! Paper §6.3: Bayesian variable selection by reversible-jump MCMC on a
//! MiniBooNE-like synthetic dataset — exact vs approximate MH tests
//! through the `Session` front-end, reporting the recovered support and
//! model size merged across chains.
//!
//! Run: cargo run --release --example rjmcmc_variable_selection

use austerity::coordinator::{Budget, ChainObserver, MhMode, Session};
use austerity::data::synthetic::sparse_logistic;
use austerity::models::rjlogistic::{RjLogisticModel, RjState};
use austerity::samplers::RjKernel;

/// Per-chain accumulator of inclusion counts and model size (the state
/// is an `RjState`, not a flat vector, so this stays a custom observer
/// plugged in through `Session::record_with`). The recorded scalar is k,
/// so the report's cross-chain R-hat / ESS come out of the same launch.
struct SupportObserver {
    incl: Vec<u64>,
    ks: u64,
    count: u64,
}

impl ChainObserver<RjState> for SupportObserver {
    fn observe(&mut self, s: &RjState) -> f64 {
        for &j in &s.active {
            self.incl[j] += 1;
        }
        self.ks += s.k() as u64;
        self.count += 1;
        s.k() as f64
    }
}

fn main() {
    let n = 40_000;
    let d = 21;
    let (ds, beta_true) = sparse_logistic(n, d, 5, 0.28, 31);
    let truly_active: Vec<usize> = (1..d).filter(|&j| beta_true[j] != 0.0).collect();
    println!("N = {n}, D = {d}, true support {truly_active:?}");

    let model = RjLogisticModel::new(ds, 1e-10);
    let chains = 2;
    let steps_per_chain = 10_000;

    for (label, mode) in [
        ("exact ", MhMode::Exact),
        ("approx", MhMode::approx(0.05, 500)),
    ] {
        let kernel = RjKernel::new(&model);
        let report = Session::new(&model)
            .kernel(&kernel)
            .rule(mode)
            .chains(chains)
            .seed(9)
            .budget(Budget::Steps(steps_per_chain))
            .burn_in(steps_per_chain / 5)
            .record_with(|_c| SupportObserver { incl: vec![0; d], ks: 0, count: 0 })
            .init(RjState::with_active(d, &[0], &[-0.9]))
            .run();
        let mut incl = vec![0u64; d];
        let mut ks = 0u64;
        let mut count = 0u64;
        for o in &report.observers {
            for (t, v) in incl.iter_mut().zip(&o.incl) {
                *t += v;
            }
            ks += o.ks;
            count += o.count;
        }
        let mut top: Vec<(usize, f64)> = (1..d)
            .map(|j| (j, incl[j] as f64 / count.max(1) as f64))
            .collect();
        top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let picked: Vec<usize> = top.iter().take(5).map(|(j, _)| *j).collect();
        let hit = picked.iter().filter(|j| truly_active.contains(j)).count();
        println!(
            "{label}: top-5 features {picked:?} ({hit}/5 correct) | mean k {:.1} | \
             accept {:.2} | data/test {:.3} | {:.0} steps/s | rhat(k) {:.2} ess {:.0}",
            ks as f64 / count.max(1) as f64,
            report.acceptance_rate(),
            report.mean_data_fraction(),
            report.steps_per_sec(),
            report.rhat(),
            report.ess(),
        );
    }
}

//! Paper §6.4: the SGLD pitfall and its repair by the approximate MH
//! test, run as `SgldKernel` chains through the `KernelSession` front-end
//! (the generic-kernel sibling of `Session`). Prints the true posterior
//! moments and the empirical moments of the uncorrected vs corrected
//! samplers, plus cross-chain R-hat / ESS.
//!
//! Run: cargo run --release --example sgld_correction

use austerity::coordinator::austerity::SeqTestConfig;
use austerity::coordinator::{Budget, KernelSession};
use austerity::data::synthetic::linreg_toy;
use austerity::models::{LinRegModel, LlDiffModel};
use austerity::samplers::sgld::{SgldConfig, SgldKernel};

fn main() {
    let model = LinRegModel::new(linreg_toy(10_000, 0), 3.0, 4950.0);

    // true posterior moments by quadrature
    let (grid, dens) = model.posterior_density(-0.2, 0.8, 4_000);
    let h = grid[1] - grid[0];
    let t_mean: f64 = grid.iter().zip(&dens).map(|(t, d)| t * d * h).sum();
    let t2: f64 = grid.iter().zip(&dens).map(|(t, d)| t * t * d * h).sum();
    let t_std = (t2 - t_mean * t_mean).sqrt();
    println!("true posterior: mean {t_mean:.4}, std {t_std:.5}");

    let chains = 2usize;
    let steps_per_chain = 20_000;
    let run = |correction: Option<SeqTestConfig>, seed: u64| {
        let kernel = SgldKernel {
            model: &model,
            cfg: SgldConfig { alpha: 5e-6, grad_batch: 50, correction },
        };
        KernelSession::new(&kernel)
            .label("sgld")
            .data_size(model.n())
            .chains(chains)
            .seed(seed)
            .budget(Budget::Steps(steps_per_chain))
            .burn_in(steps_per_chain / 5)
            .init(t_mean)
            .run()
    };

    let res_un = run(None, 0);
    println!(
        "uncorrected SGLD: mean {:.4}, std {:.5}  <- {:.1}x too wide (rhat {:.2})",
        res_un.pooled_mean(),
        res_un.pooled_std(),
        res_un.pooled_std() / t_std,
        res_un.rhat(),
    );

    let res_co = run(Some(SeqTestConfig::new(0.5, 500)), 1);
    println!(
        "corrected  SGLD: mean {:.4}, std {:.5}  (accept {:.2}, {} data pts/step, \
         rhat {:.2}, ess {:.0})",
        res_co.pooled_mean(),
        res_co.pooled_std(),
        res_co.acceptance_rate(),
        res_co.merged.data_used / res_co.merged.steps as u64,
        res_co.rhat(),
        res_co.ess(),
    );
    println!(
        "\nwith eps = 0.5 the test decides from the first mini-batch \
         (m = 500) — O(N) work avoided while removing the SGLD bias; \
         {chains} chains ran in parallel on the engine"
    );
}

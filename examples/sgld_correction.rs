//! Paper §6.4: the SGLD pitfall and its repair by the approximate MH
//! test. Prints the true posterior moments and the empirical moments of
//! the uncorrected vs corrected samplers.
//!
//! Run: cargo run --release --example sgld_correction

use austerity::coordinator::austerity::SeqTestConfig;
use austerity::data::synthetic::linreg_toy;
use austerity::models::LinRegModel;
use austerity::samplers::sgld::{run_sgld, SgldConfig};
use austerity::stats::welford::Welford;
use austerity::stats::Pcg64;

fn moments(xs: &[f64]) -> (f64, f64) {
    let mut w = Welford::new();
    for &x in xs {
        w.add(x);
    }
    (w.mean(), w.var_pop().sqrt())
}

fn main() {
    let model = LinRegModel::new(linreg_toy(10_000, 0), 3.0, 4950.0);

    // true posterior moments by quadrature
    let (grid, dens) = model.posterior_density(-0.2, 0.8, 4_000);
    let h = grid[1] - grid[0];
    let t_mean: f64 = grid.iter().zip(&dens).map(|(t, d)| t * d * h).sum();
    let t2: f64 = grid.iter().zip(&dens).map(|(t, d)| t * t * d * h).sum();
    let t_std = (t2 - t_mean * t_mean).sqrt();
    println!("true posterior: mean {t_mean:.4}, std {t_std:.5}");

    let steps = 40_000;
    let mut rng = Pcg64::seeded(0);

    let un = SgldConfig { alpha: 5e-6, grad_batch: 50, correction: None };
    let (s_un, _) = run_sgld(&model, &un, t_mean, steps, steps / 5, &mut rng);
    let (m, s) = moments(&s_un);
    println!(
        "uncorrected SGLD: mean {m:.4}, std {s:.5}  <- {:.1}x too wide",
        s / t_std
    );

    let co = SgldConfig {
        alpha: 5e-6,
        grad_batch: 50,
        correction: Some(SeqTestConfig::new(0.5, 500)),
    };
    let (s_co, stats) = run_sgld(&model, &co, t_mean, steps, steps / 5, &mut rng);
    let (m, s) = moments(&s_co);
    println!(
        "corrected  SGLD: mean {m:.4}, std {s:.5}  (accept {:.2}, {} data pts/step)",
        stats.accepted as f64 / stats.steps as f64,
        stats.data_used / stats.steps as u64,
    );
    println!(
        "\nwith eps = 0.5 the test decides from the first mini-batch \
         (m = 500) — O(N) work avoided while removing the SGLD bias"
    );
}

//! Quickstart: the approximate MH test in five minutes.
//!
//! Builds a small logistic-regression posterior, runs the exact MH chain
//! and the approximate (sequential-test) chain side by side, and prints
//! the headline numbers: matching posteriors, a fraction of the data
//! touched per decision, and more samples per second.
//!
//! Run: cargo run --release --example quickstart

use austerity::coordinator::{run_chain, Budget, MhMode};
use austerity::data::synthetic::two_class_gaussian;
use austerity::models::{LlDiffModel, LogisticModel};
use austerity::samplers::GaussianRandomWalk;
use austerity::stats::welford::Welford;
use austerity::stats::Pcg64;

fn main() {
    // 1. A posterior over 12214 datapoints (synthetic stand-in for the
    //    paper's MNIST 7-vs-9 PCA features).
    let model = LogisticModel::new(two_class_gaussian(12_214, 20, 1.2, 0), 10.0);
    let init = model.map_estimate(60);
    let kernel = GaussianRandomWalk::new(0.01, model.prior_precision);

    // 2. Run both chains for the same number of steps.
    let steps = 2_000;
    let mut results = Vec::new();
    for (label, mode) in [
        ("exact  (eps=0)   ", MhMode::Exact),
        ("approx (eps=0.05)", MhMode::approx(0.05, 500)),
    ] {
        let mut rng = Pcg64::seeded(1);
        let t0 = std::time::Instant::now();
        let (samples, stats) = run_chain(
            &model,
            &kernel,
            &mode,
            init.clone(),
            Budget::Steps(steps),
            200,
            1,
            |theta| theta[0], // posterior of the first coefficient
            &mut rng,
        );
        let secs = t0.elapsed().as_secs_f64();
        let mut w = Welford::new();
        for s in &samples {
            w.add(s.value);
        }
        println!(
            "{label}: E[theta_0] = {:+.4} +- {:.4} | accept {:.2} | \
             data/test {:.3} | {:.0} steps/s",
            w.mean(),
            w.std_sample(),
            stats.acceptance_rate(),
            stats.mean_data_fraction(model.n()),
            steps as f64 / secs,
        );
        results.push((w.mean(), stats.mean_data_fraction(model.n())));
    }

    // 3. The point of the paper in two lines:
    let (exact_mean, _) = results[0];
    let (approx_mean, approx_frac) = results[1];
    println!(
        "\nsame posterior ({:+.4} vs {:+.4}) from {:.0}% of the data per decision",
        exact_mean,
        approx_mean,
        approx_frac * 100.0
    );
}

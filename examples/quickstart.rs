//! Quickstart: budgeted Metropolis-Hastings in five minutes.
//!
//! Builds a small logistic-regression posterior and runs all four
//! acceptance rules through the `Session` front-end — the exact
//! full-data test, the paper's sequential (austerity) test, the
//! minibatch Barker test and the confidence sampler — K chains on K
//! cores. The cached fast path is picked automatically (the model keeps
//! per-datapoint activations alive across steps), and cross-chain R-hat
//! comes back in the same `RunReport`. The headline numbers: matching
//! posteriors, a fraction of the data touched per decision, and more
//! samples per second.
//!
//! Run: cargo run --release --example quickstart

use austerity::coordinator::{Budget, MhMode, Param, Session};
use austerity::data::synthetic::two_class_gaussian;
use austerity::models::LogisticModel;
use austerity::samplers::GaussianRandomWalk;

fn main() {
    // 1. A posterior over 12214 datapoints (synthetic stand-in for the
    //    paper's MNIST 7-vs-9 PCA features).
    let model = LogisticModel::new(two_class_gaussian(12_214, 20, 1.2, 0), 10.0);
    let init = model.map_estimate(60);
    let kernel = GaussianRandomWalk::new(0.01, model.prior_precision);

    // 2. One MhMode per acceptance rule: 2 chains x 1000 steps each.
    let chains = 2;
    let steps_per_chain = 1_000;
    let mut results = Vec::new();
    for (label, mode) in [
        ("exact      (full scan) ", MhMode::Exact),
        ("austerity  (eps = 0.05)", MhMode::approx(0.05, 500)),
        ("barker     (sigma = 1) ", MhMode::barker(1.0, 500)),
        ("confidence (delta=0.05)", MhMode::confidence(0.05, 500)),
    ] {
        let report = Session::new(&model)
            .kernel(&kernel)
            .rule(mode)
            .chains(chains)
            .seed(1)
            .budget(Budget::Steps(steps_per_chain))
            .burn_in(100)
            .record(Param::index(0)) // posterior of the first coefficient
            .init(init.clone())
            .run();
        println!(
            "{label}: E[theta_0] = {:+.4} +- {:.4} | accept {:.2} | \
             data/test {:.3} | {:.0} steps/s | R-hat {:.3}",
            report.pooled_mean(),
            report.pooled_std(),
            report.acceptance_rate(),
            report.mean_data_fraction(),
            report.steps_per_sec(),
            report.rhat(),
        );
        results.push((report.pooled_mean(), report.mean_data_fraction()));
    }

    // 3. The point of the whole family in two lines:
    let (exact_mean, _) = results[0];
    for ((mean, frac), name) in results[1..].iter().zip(["austerity", "barker", "confidence"]) {
        println!(
            "{name}: same posterior ({exact_mean:+.4} vs {mean:+.4}) from {:.0}% of the data \
             per decision",
            frac * 100.0
        );
    }
}

//! Paper supp. F: approximate Gibbs sampling on a dense binary MRF with
//! C(D,3) triple potentials. Each conditional flip needs 4851 potential
//! pairs at D = 100; the sequential test decides from a few hundred.
//! Each mode runs as a `GibbsSweepKernel` launch through the
//! `KernelSession` front-end (2 chains in parallel, cross-chain R-hat
//! for free).
//!
//! Run: cargo run --release --example gibbs_mrf [-- D]

use austerity::coordinator::{Budget, KernelSession, ScalarFn};
use austerity::models::MrfModel;
use austerity::samplers::gibbs::{GibbsMode, GibbsSweepKernel};
use austerity::stats::Pcg64;

fn main() {
    let d: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(100);
    println!(
        "building MRF: D = {d}, {} triple potentials, {} pairs per conditional",
        d * (d - 1) * (d - 2) / 6,
        (d - 1) * (d - 2) / 2
    );
    let model = MrfModel::random(d, 0.02, 1);
    let chains = 2usize;
    let sweeps_per_chain = 100;

    let mut rng = Pcg64::seeded(2);
    let x0: Vec<bool> = (0..d).map(|_| rng.uniform() < 0.5).collect();

    println!("\nmode          sweeps/s   pairs/update   P(X=1) avg   rhat");
    for (label, mode) in [
        ("exact       ", GibbsMode::Exact),
        ("approx e=.05", GibbsMode::Approx { eps: 0.05, batch: 500 }),
        ("approx e=.10", GibbsMode::Approx { eps: 0.1, batch: 500 }),
        ("approx e=.20", GibbsMode::Approx { eps: 0.2, batch: 500 }),
    ] {
        let kernel = GibbsSweepKernel { model: &model, mode };
        let report = KernelSession::new(&kernel)
            .label("gibbs")
            .chains(chains)
            .seed(2)
            .budget(Budget::Steps(sweeps_per_chain))
            .record(ScalarFn::new(|x: &Vec<bool>| {
                x.iter().filter(|&&b| b).count() as f64 / x.len() as f64
            }))
            .init(x0.clone())
            .run();
        println!(
            "{label}  {:>7.1}    {:>8.0}       {:.3}      {:.2}",
            report.steps_per_sec(),
            report.merged.data_used as f64 / (report.merged.steps * d) as f64,
            report.pooled_mean(),
            report.rhat(),
        );
    }
}

//! Paper supp. F: approximate Gibbs sampling on a dense binary MRF with
//! C(D,3) triple potentials. Each conditional flip needs 4851 potential
//! pairs at D = 100; the sequential test decides from a few hundred.
//!
//! Run: cargo run --release --example gibbs_mrf [-- D]

use austerity::models::MrfModel;
use austerity::samplers::gibbs::{gibbs_sweep, GibbsMode, GibbsScratch, GibbsStats};
use austerity::stats::Pcg64;

fn main() {
    let d: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(100);
    println!(
        "building MRF: D = {d}, {} triple potentials, {} pairs per conditional",
        d * (d - 1) * (d - 2) / 6,
        (d - 1) * (d - 2) / 2
    );
    let model = MrfModel::random(d, 0.02, 1);
    let sweeps = 200;

    println!("\nmode          sweeps/s   pairs/update   P(X=1) avg");
    for (label, mode) in [
        ("exact       ", GibbsMode::Exact),
        ("approx e=.05", GibbsMode::Approx { eps: 0.05, batch: 500 }),
        ("approx e=.10", GibbsMode::Approx { eps: 0.1, batch: 500 }),
        ("approx e=.20", GibbsMode::Approx { eps: 0.2, batch: 500 }),
    ] {
        let mut rng = Pcg64::seeded(2);
        let mut x: Vec<bool> = (0..d).map(|_| rng.uniform() < 0.5).collect();
        let mut scratch = GibbsScratch::new(&model);
        let mut stats = GibbsStats::default();
        let t0 = std::time::Instant::now();
        for _ in 0..sweeps {
            gibbs_sweep(&model, &mut x, &mode, &mut scratch, &mut stats, &mut rng);
        }
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "{label}  {:>7.1}    {:>8.0}       {:.3}",
            sweeps as f64 / secs,
            stats.pairs_used as f64 / stats.updates as f64,
            stats.ones_assigned as f64 / stats.updates as f64,
        );
    }
}
